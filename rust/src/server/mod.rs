//! TCP serving frontend + blocking client.
//!
//! Line-delimited JSON, protocol v1 (DESIGN.md §Serving API v1): one
//! connection multiplexes many in-flight requests. Envelopes in,
//! `req_id`-tagged frames out:
//!
//!   -> {"v":1,"req_id":7,"prompt":[1,2,3],"stream":true,
//!       "max_new_tokens":64,"temperature":0.6,"seed":42}
//!   <- {"v":1,"req_id":7,"event":"chunk","tokens":[...],"round":1,...}
//!   <- {"v":1,"req_id":7,"event":"chunk","tokens":[...],"round":2,...}
//!   -> {"v":1,"req_id":8,"prompt":[9],"stream":false}      (interleaved)
//!   -> {"cmd":"cancel","req_id":7}
//!   <- {"v":1,"req_id":7,"event":"done","finish":"cancelled",...}
//!   <- {"v":1,"req_id":8,"event":"done","finish":"length","tokens":[...]}
//!   -> {"cmd":"stats"}
//!   <- {"admitted":...,"completed":...,"cancelled":...,...}
//!   -> {"cmd":"shutdown"}        (stops the transport)
//!
//! **Transport (DESIGN.md §Transport):** a reactor, not
//! thread-per-connection. A nonblocking listener and every accepted
//! socket are driven by a fixed pool of `server.reactor_threads` event
//! loops (epoll on Linux, a portable readiness tick elsewhere —
//! `server/reactor.rs`); each connection is a state machine
//! (`server/conn.rs`) owning an incremental frame decoder and a bounded
//! outbox. Worker `GenEvent`s are serialized into frames by the
//! request's `ConnSink` and land directly in the connection outbox,
//! waking the owning reactor — there are no per-request forwarder
//! threads and no per-connection reader/writer threads, so server-side
//! thread count is O(reactor_threads + workers), not O(connections).
//!
//! Admission control and backpressure: more than `server.max_conns`
//! concurrent connections are refused at accept with a
//! `{"error":"server at capacity"}` line; a client that stops draining
//! its socket until `server.outbox_frames` frames pile up is treated as
//! gone (connection closed, in-flight work cancelled,
//! `backpressure_closed` counted).
//!
//! Behind admission sits the router tier (`router/`, DESIGN.md §Router
//! Tier): every submitted request is placed onto one of `workers`
//! per-worker queues by consistent-hashing its prompt prefix
//! (`route=affinity`) or round-robin (`route=rr`); queue-full
//! backpressure and "queue closed" (worker killed mid-flight) surface
//! through the same error frame as before. The transport is unaware of
//! worker count — `try_submit_sink` hides the placement.
//!
//! A request that cannot start (bad envelope, queue-full backpressure)
//! gets {"v":1,"req_id":..,"event":"error","error":"..."}; un-enveloped
//! parse errors get the legacy {"error":"..."} line. Legacy un-enveloped
//! generates ({"prompt":[...]} with no req_id) keep v0's contract — one
//! one-shot reply each, in submission order — via a per-connection FIFO
//! (one legacy request in flight at a time); enveloped traffic flows
//! concurrently even while a legacy request runs, which the blocking
//! transport could not do.
//!
//! Disconnect handling: when the client side goes away, the reactor
//! observes EOF (or a failed frame write) on the nonblocking socket and
//! cancels every in-flight request of that connection — slots and KV
//! residency are released within one speculation round. This replaces
//! the old destructive-`peek` polling (`peer_gone`) that the legacy
//! blocking-wait path used, which raced with interleaved v1 traffic.

pub mod client;
pub mod conn;
pub mod protocol;
pub mod reactor;

use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::Coordinator;
use crate::{log_info, log_warn};

use conn::{Conn, ConnShared, TransportCtl};
use reactor::{raw_fd, Event, Interest, Poller, ReactorHandle, LISTENER_TOKEN};

pub use client::Client;
pub use protocol::{
    ClientMessage, Frame, FrameDecoder, ServerReply, PROTOCOL_VERSION,
};

/// Idle poll ceiling: a reactor with nothing to do wakes at least this
/// often to observe the stop flag (wakeups cut it short).
const IDLE_WAIT: Duration = Duration::from_millis(100);

/// Serve `coordinator` on `addr` until a shutdown command arrives.
/// Returns the bound local address once listening (port 0 supported).
pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn bind(addr: &str, coordinator: Arc<Coordinator>) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            listener,
            coordinator,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Run the reactor transport until shutdown: this thread becomes
    /// reactor 0 (it owns the accept loop); `server.reactor_threads - 1`
    /// more event loops are spawned. All are joined before returning.
    pub fn run(&self) -> std::io::Result<()> {
        log_info!("serving on {}", self.local_addr()?);
        let scfg = self.coordinator.server_config().clone();
        let n_reactors = scfg.reactor_threads.max(1);
        self.listener.set_nonblocking(true)?;
        self.coordinator
            .metrics
            .set_transport_threads(n_reactors as u64);

        let mut parts: Vec<(Poller, Arc<ReactorHandle>)> = Vec::new();
        for _ in 0..n_reactors {
            let poller = Poller::new()?;
            let handle = ReactorHandle::new(poller.waker());
            parts.push((poller, handle));
        }
        let wakers = parts.iter().map(|(p, _)| p.waker()).collect();
        let assign: Vec<Arc<ReactorHandle>> =
            parts.iter().map(|(_, h)| h.clone()).collect();
        let ctl = Arc::new(TransportCtl {
            coord: self.coordinator.clone(),
            stop: self.stop.clone(),
            wakers,
        });
        let conn_seq = Arc::new(AtomicU64::new(1));
        // Every fallible setup step happens BEFORE any thread is
        // spawned — an error after the spawn loop would leak reactors
        // that only exit on the stop flag.
        let listener = self.listener.try_clone()?;

        let mut joins = Vec::new();
        for (tid, (poller, handle)) in parts.drain(1..).enumerate() {
            let rt = ReactorThread {
                tid: tid + 1,
                poller,
                handle,
                ctl: ctl.clone(),
                outbox_cap: scfg.outbox_frames,
                max_conns: scfg.max_conns,
                listener: None,
                assign: Vec::new(),
                conn_seq: conn_seq.clone(),
            };
            joins.push(
                std::thread::Builder::new()
                    .name(format!("dyspec-reactor-{}", tid + 1))
                    .spawn(move || reactor_loop(rt))
                    .expect("spawning reactor thread"),
            );
        }
        let (poller, handle) = parts.pop().expect("reactor 0 parts");
        let rt = ReactorThread {
            tid: 0,
            poller,
            handle,
            ctl,
            outbox_cap: scfg.outbox_frames,
            max_conns: scfg.max_conns,
            listener: Some(listener),
            assign,
            conn_seq,
        };
        reactor_loop(rt);
        for join in joins {
            let _ = join.join();
        }
        Ok(())
    }
}

/// Everything one reactor thread owns.
struct ReactorThread {
    tid: usize,
    poller: Poller,
    /// This thread's mailbox (dirty connections, injected sockets).
    handle: Arc<ReactorHandle>,
    ctl: Arc<TransportCtl>,
    outbox_cap: usize,
    max_conns: usize,
    /// Reactor 0 owns the accept loop...
    listener: Option<TcpListener>,
    /// ...and round-robins accepted sockets over every reactor.
    assign: Vec<Arc<ReactorHandle>>,
    conn_seq: Arc<AtomicU64>,
}

fn reactor_loop(mut rt: ReactorThread) {
    if let Some(listener) = &rt.listener {
        if let Err(e) =
            rt.poller
                .register(raw_fd(listener), LISTENER_TOKEN, Interest::READ)
        {
            log_warn!("reactor {}: listener register failed: {e}", rt.tid);
            broadcast_stop(&rt.ctl);
            return;
        }
    }
    let mut conns: HashMap<usize, Conn> = HashMap::new();
    let mut events: Vec<Event> = Vec::new();
    // Tokens whose connection state may have changed this iteration —
    // the only ones the sweep must look at.
    let mut touched: Vec<usize> = Vec::new();
    loop {
        events.clear();
        if let Err(e) = rt.poller.wait(&mut events, IDLE_WAIT) {
            log_warn!("reactor {}: poll failed: {e}", rt.tid);
            break;
        }
        if rt.ctl.stop.load(Ordering::SeqCst) {
            break;
        }
        for (id, stream) in rt.handle.take_injected() {
            register_conn(&mut rt, &mut conns, id, stream);
            touched.push(id as usize);
        }
        let ready = std::mem::take(&mut events);
        for ev in &ready {
            if ev.token == LISTENER_TOKEN {
                accept_ready(&mut rt, &mut conns, &mut touched);
                continue;
            }
            if let Some(conn) = conns.get_mut(&ev.token) {
                touched.push(ev.token);
                if ev.readable {
                    conn.on_readable(&rt.ctl);
                }
                if !conn.closed {
                    conn.pump_out(&rt.ctl);
                }
            }
        }
        events = ready;
        for id in rt.handle.take_dirty() {
            if let Some(conn) = conns.get_mut(&(id as usize)) {
                touched.push(id as usize);
                conn.on_dirty(&rt.ctl);
            }
        }
        sweep(&mut rt.poller, &mut conns, &mut touched);
    }
    // Whatever got us here — shutdown command or a poller failure — the
    // whole transport goes down together: a lone dead reactor would
    // otherwise hang Server::run's join (reactor 0) or keep receiving
    // round-robined connections that are never served (reactor N>0).
    broadcast_stop(&rt.ctl);
    // Shutdown: flush what is queued (the `ok` reply to the shutdown
    // command in particular), cancel all in-flight work, close.
    for conn in conns.values_mut() {
        conn.flush_blocking(&rt.ctl);
    }
    for (id, conn) in conns.drain() {
        let _ = rt.poller.deregister(conn.fd(), id);
    }
    for (_, stream) in rt.handle.take_injected() {
        drop(stream);
        rt.ctl.coord.metrics.on_conn_closed();
    }
}

/// Stop every reactor: set the shared flag and wake all event loops.
/// Idempotent — the normal shutdown path re-broadcasts harmlessly.
fn broadcast_stop(ctl: &TransportCtl) {
    ctl.stop.store(true, Ordering::SeqCst);
    for waker in &ctl.wakers {
        waker.wake();
    }
}

fn register_conn(
    rt: &mut ReactorThread,
    conns: &mut HashMap<usize, Conn>,
    id: u64,
    stream: TcpStream,
) {
    let shared = ConnShared::new(
        id,
        rt.outbox_cap,
        rt.handle.clone(),
        rt.ctl.coord.metrics.clone(),
    );
    let mut conn = Conn::new(stream, shared);
    match rt.poller.register(conn.fd(), id as usize, Interest::READ) {
        Ok(()) => {
            conns.insert(id as usize, conn);
        }
        Err(e) => {
            log_warn!("conn {id}: register failed: {e}");
            conn.close(&rt.ctl, "poller register failed");
        }
    }
}

/// Accept until the listener would block (reactor 0 only). Connections
/// beyond `max_conns` are refused with an error line — admission
/// control, so a connection flood degrades into fast rejections instead
/// of unbounded kernel/server state.
fn accept_ready(
    rt: &mut ReactorThread,
    conns: &mut HashMap<usize, Conn>,
    touched: &mut Vec<usize>,
) {
    loop {
        let Some(listener) = rt.listener.as_ref() else {
            return;
        };
        match listener.accept() {
            Ok((stream, _peer)) => {
                let metrics = &rt.ctl.coord.metrics;
                if metrics.open_conns() >= rt.max_conns as u64 {
                    metrics.on_conn_rejected();
                    reject_at_capacity(stream);
                    continue;
                }
                let _ = stream.set_nonblocking(true);
                let _ = stream.set_nodelay(true);
                metrics.on_conn_open();
                let id = rt.conn_seq.fetch_add(1, Ordering::Relaxed);
                let target = (id as usize) % rt.assign.len().max(1);
                if target == rt.tid {
                    register_conn(rt, conns, id, stream);
                    touched.push(id as usize);
                } else {
                    rt.assign[target].inject(id, stream);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                log_warn!("accept error: {e}");
                break;
            }
        }
    }
}

/// Best-effort refusal line for a connection over the admission limit.
/// One nonblocking write and drop — a flood of rejected peers must
/// never stall the accept loop (and with it every connection owned by
/// reactor 0), so no blocking I/O happens here: a freshly-accepted
/// socket's send buffer is empty, so the short line fits or the peer
/// simply sees the close.
fn reject_at_capacity(mut stream: TcpStream) {
    let _ = stream.set_nonblocking(true);
    let mut line = protocol::error_json("server at capacity").to_string();
    line.push('\n');
    // Nonblocking write_all: it errors out (WouldBlock) instead of
    // parking the thread if the peer's buffer is somehow already full.
    let _ = stream.write_all(line.as_bytes());
}

/// Drop the closed connections among `touched` and reconcile poller
/// write-interest with each survivor's queued output (level-triggered
/// epoll: EPOLLOUT is armed only while there is something to write).
/// Only connections touched this iteration (readiness event, dirty
/// notification, or injection) can have changed state, so the sweep is
/// O(touched), not O(open connections).
fn sweep(
    poller: &mut Poller,
    conns: &mut HashMap<usize, Conn>,
    touched: &mut Vec<usize>,
) {
    touched.sort_unstable();
    touched.dedup();
    for &k in touched.iter() {
        let Some(conn) = conns.get_mut(&k) else {
            continue;
        };
        if conn.closed {
            if let Some(conn) = conns.remove(&k) {
                let _ = poller.deregister(conn.fd(), k);
            }
            continue;
        }
        let want = conn.wants_write();
        if want != conn.registered_write
            && poller
                .reregister(conn.fd(), k, Interest::rw(want))
                .is_ok()
        {
            conn.registered_write = want;
        }
    }
    touched.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::{GenParams, ModelFactory};
    use crate::models::sim::{SimModel, SimSpec};
    use crate::models::LogitModel;

    fn start_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let factory: ModelFactory = Arc::new(|| {
            let spec = SimSpec::new(64, 2.0, 0.5, 9);
            let (d, t) = SimModel::pair(spec);
            (
                Box::new(d) as Box<dyn LogitModel>,
                Box::new(t) as Box<dyn LogitModel>,
            )
        });
        let mut cfg = Config::new();
        cfg.server.workers = 2;
        cfg.engine.tree_budget = 8;
        let coord = Arc::new(Coordinator::start(cfg, factory));
        let server = Server::bind("127.0.0.1:0", coord).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let _ = server.run();
        });
        (addr, handle)
    }

    #[test]
    fn end_to_end_generate_stats_shutdown() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let tokens = client.generate(&[1, 2, 3], 12, 0.6).unwrap();
        assert_eq!(tokens.len(), 12);

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize(), Some(1));

        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn streamed_generate_over_tcp() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let mut chunks = 0usize;
        let (tokens, done) = client
            .generate_stream(7, &[1, 2, 3], &GenParams::simple(12, 0.6), |_| {
                chunks += 1;
            })
            .unwrap();
        assert_eq!(tokens.len(), 12);
        assert!(chunks >= 1);
        assert_eq!(done.finish().unwrap().name(), "length");
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn malformed_line_returns_error() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let reply = client.send_raw("this is not json").unwrap();
        assert!(reply.get("error").is_some());
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    /// A split-up envelope (one byte per TCP write) decodes and serves
    /// exactly like a whole line — the incremental decoder satellite,
    /// over a real socket.
    #[test]
    fn byte_dribbled_envelope_is_served() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let line = protocol::generate_envelope(
            3,
            &[5, 6],
            &GenParams::simple(6, 0.6),
            false,
        )
        .to_string();
        {
            let raw = client.writer_mut();
            for b in line.as_bytes() {
                raw.write_all(std::slice::from_ref(b)).unwrap();
                raw.flush().unwrap();
            }
            raw.write_all(b"\n").unwrap();
            raw.flush().unwrap();
        }
        let frame = client.read_frame().unwrap();
        assert_eq!(frame.req_id, Some(3));
        assert_eq!(frame.event, "done");
        assert_eq!(frame.tokens().len(), 6);
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    /// An over-long line gets the connection closed (with a best-effort
    /// error line) instead of being buffered without bound, and the
    /// server stays healthy for new connections.
    #[test]
    fn oversized_line_errors_and_closes() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let flood = "x".repeat(protocol::MAX_LINE_BYTES + 2);
        // The server may close (RST) while we are still flooding; both
        // halves of the exchange are allowed to fail from our side —
        // what matters is that the connection dies and the server lives.
        let _ = client.send_line(&flood);
        let mut closed = false;
        for _ in 0..2 {
            match client.read_json() {
                Ok(reply) => {
                    let msg = reply
                        .get("error")
                        .and_then(crate::util::json::Json::as_str)
                        .expect("non-error reply to an oversized line");
                    assert!(msg.contains("exceeds"), "unexpected error: {msg}");
                }
                Err(_) => {
                    closed = true;
                    break;
                }
            }
        }
        assert!(closed, "connection stayed open after an oversized line");
        let mut c2 = Client::connect(&addr.to_string()).unwrap();
        let tokens = c2.generate(&[1, 2], 4, 0.6).unwrap();
        assert_eq!(tokens.len(), 4);
        c2.shutdown().unwrap();
        handle.join().unwrap();
    }
}
