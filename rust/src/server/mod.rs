//! TCP serving frontend + blocking client.
//!
//! Line-delimited JSON, protocol v1 (DESIGN.md §Serving API v1): one
//! connection multiplexes many in-flight requests. Envelopes in,
//! `req_id`-tagged frames out:
//!
//!   -> {"v":1,"req_id":7,"prompt":[1,2,3],"stream":true,
//!       "max_new_tokens":64,"temperature":0.6,"seed":42}
//!   <- {"v":1,"req_id":7,"event":"chunk","tokens":[...],"round":1,...}
//!   <- {"v":1,"req_id":7,"event":"chunk","tokens":[...],"round":2,...}
//!   -> {"v":1,"req_id":8,"prompt":[9],"stream":false}      (interleaved)
//!   -> {"cmd":"cancel","req_id":7}
//!   <- {"v":1,"req_id":7,"event":"done","finish":"cancelled",...}
//!   <- {"v":1,"req_id":8,"event":"done","finish":"length","tokens":[...]}
//!   -> {"cmd":"stats"}
//!   <- {"admitted":...,"completed":...,"cancelled":...,...}
//!   -> {"cmd":"shutdown"}        (stops the accept loop)
//!
//! A request that cannot start (bad envelope, queue-full backpressure)
//! gets {"v":1,"req_id":..,"event":"error","error":"..."}; un-enveloped
//! parse errors get the legacy {"error":"..."} line. Legacy un-enveloped
//! generates ({"prompt":[...]} with no req_id) are served blocking with
//! the one-shot reply object, exactly as before protocol v1.
//!
//! Disconnect handling: when the client side goes away (reader EOF or a
//! failed frame write), every in-flight request of that connection is
//! cancelled — its scheduler slot and KV residency are released within
//! one speculation round, and nothing panics on writes to the dead
//! socket (the writer thread simply drains and exits).

pub mod client;
pub mod protocol;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::coordinator::{CancelToken, Coordinator, GenEvent, GenParams};
use crate::util::json::{parse as parse_json, Json};
use crate::{log_info, log_warn};

pub use client::Client;
pub use protocol::{ClientMessage, Frame, ServerReply, PROTOCOL_VERSION};

/// Serve `coordinator` on `addr` until a shutdown command arrives.
/// Returns the bound local address once listening (port 0 supported).
pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn bind(addr: &str, coordinator: Arc<Coordinator>) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            listener,
            coordinator,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept loop: one reader thread per connection plus one writer
    /// thread serializing the connection's interleaved frames
    /// (connections are few and long-lived in this workload; the worker
    /// pool bounds real concurrency).
    pub fn run(&self) -> std::io::Result<()> {
        log_info!("serving on {}", self.local_addr()?);
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let coord = self.coordinator.clone();
                    let stop = self.stop.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, &coord, &stop) {
                            log_warn!("connection error: {e}");
                        }
                    });
                }
                Err(e) => log_warn!("accept error: {e}"),
            }
        }
        Ok(())
    }
}

/// In-flight requests of one connection: client req_id → cancel token.
type Inflight = Arc<Mutex<HashMap<u64, CancelToken>>>;

/// Is the peer of `probe` gone? Non-destructive (peek, never reads), used
/// while a legacy blocking generate is in flight and nothing else is
/// reading the socket. Requires a read timeout on `probe` to not block.
fn peer_gone(probe: &TcpStream) -> bool {
    let mut buf = [0u8; 1];
    match probe.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ),
    }
}

fn handle_conn(
    stream: TcpStream,
    coord: &Arc<Coordinator>,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let local = stream.local_addr()?;
    // Second handle on the socket for EOF detection during legacy
    // blocking waits (peek only — never consumes bytes the reader owns).
    let probe = stream.try_clone()?;

    // Single writer serializes frames from the reader (command replies)
    // and from per-request forwarder threads (chunk/done frames). A write
    // failure means the client is gone: the writer drains quietly and the
    // reader's EOF takes care of cancellation.
    let (frame_tx, frame_rx) = mpsc::channel::<String>();
    let mut write_half = stream.try_clone()?;
    let writer = std::thread::spawn(move || {
        for line in frame_rx {
            if write_half
                .write_all(line.as_bytes())
                .and_then(|_| write_half.write_all(b"\n"))
                .and_then(|_| write_half.flush())
                .is_err()
            {
                break; // client gone; drain remaining frames unsent
            }
        }
    });

    let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
    let send = |json: protocol::ServerReply| {
        let _ = frame_tx.send(json.to_string());
    };

    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(line) => line,
            Err(_) => break, // client gone mid-line
        };
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_client_message(&line) {
            Ok(ClientMessage::Generate {
                req_id: Some(req_id),
                prompt,
                params,
                stream,
            }) => spawn_request(
                coord, &inflight, &frame_tx, req_id, prompt, params, stream,
            ),
            Ok(ClientMessage::Generate {
                req_id: None,
                prompt,
                params,
                ..
            }) => {
                // Legacy one-shot: blocking, so replies stay in submission
                // order even for pipelined v0 clients — but the wait polls
                // the socket for EOF (peek, non-destructive) so a client
                // that vanished mid-generate cancels its request instead
                // of running it to completion.
                match coord.try_submit(prompt, params) {
                    Err(e) => send(protocol::error_json(&e)),
                    Ok(handle) => {
                        let _ = probe
                            .set_read_timeout(Some(Duration::from_millis(10)));
                        let resp = loop {
                            match handle
                                .events
                                .recv_timeout(Duration::from_millis(50))
                            {
                                Ok(GenEvent::Done(resp)) => break Some(resp),
                                Ok(GenEvent::Chunk { .. }) => {}
                                Err(mpsc::RecvTimeoutError::Timeout) => {
                                    // Keep looping after cancel: the
                                    // Done(cancelled) arrives within one
                                    // round and tears down cleanly.
                                    if peer_gone(&probe) {
                                        handle.cancel.cancel();
                                    }
                                }
                                Err(
                                    mpsc::RecvTimeoutError::Disconnected,
                                ) => break None,
                            }
                        };
                        let _ = probe.set_read_timeout(None);
                        match resp {
                            Some(resp) => {
                                send(protocol::response_json(&resp))
                            }
                            None => send(protocol::error_json(
                                "worker dropped request",
                            )),
                        }
                    }
                }
            }
            Ok(ClientMessage::Cancel { req_id }) => {
                // Fire-and-forget and idempotent: the request's own `done`
                // frame (finish:"cancelled") is the acknowledgement, and a
                // cancel racing the request's natural completion is normal
                // — an unknown/finished id is a silent no-op, because a
                // second terminal frame would violate the exactly-one-
                // done|error stream contract.
                if let Some(token) = inflight.lock().unwrap().get(&req_id) {
                    token.cancel();
                }
            }
            Ok(ClientMessage::Stats) => send(coord.metrics.snapshot()),
            Ok(ClientMessage::Shutdown) => {
                stop.store(true, Ordering::SeqCst);
                send(protocol::ok_json());
                // Poke the accept loop awake.
                let _ = TcpStream::connect(local);
            }
            Err(e) => {
                // Attribute the failure to the envelope's req_id whenever
                // one is recoverable so the submitter's stream still gets
                // its terminal frame (a healthy concurrent stream must
                // never see an un-attributed error); otherwise fall back
                // to the legacy error object.
                let req_id = parse_json(&line).ok().and_then(|doc| {
                    doc.get("req_id")
                        .and_then(Json::as_f64)
                        .map(|v| v as u64)
                });
                match req_id {
                    Some(req_id) => send(protocol::error_frame(req_id, &e)),
                    None => send(protocol::error_json(&e)),
                }
            }
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }

    // Reader is done (disconnect or shutdown): cancel every request this
    // connection still has in flight so slots and KV residency free up.
    let orphaned: Vec<CancelToken> =
        inflight.lock().unwrap().values().cloned().collect();
    for token in orphaned {
        token.cancel();
    }
    drop(frame_tx);
    let _ = writer.join();
    log_info!("peer {peer} disconnected");
    Ok(())
}

/// Submit one enveloped request and spawn its event forwarder.
fn spawn_request(
    coord: &Arc<Coordinator>,
    inflight: &Inflight,
    frame_tx: &mpsc::Sender<String>,
    req_id: u64,
    prompt: Vec<u32>,
    params: GenParams,
    stream: bool,
) {
    {
        let mut map = inflight.lock().unwrap();
        if map.contains_key(&req_id) {
            let _ = frame_tx.send(
                protocol::error_frame(req_id, "req_id already in flight")
                    .to_string(),
            );
            return;
        }
        let handle = match coord.try_submit(prompt, params) {
            Ok(handle) => handle,
            Err(e) => {
                let _ = frame_tx
                    .send(protocol::error_frame(req_id, &e).to_string());
                return;
            }
        };
        map.insert(req_id, handle.cancel.clone());
        let frame_tx = frame_tx.clone();
        let inflight = inflight.clone();
        std::thread::spawn(move || {
            loop {
                match handle.events.recv() {
                    Ok(GenEvent::Chunk { tokens, stats }) => {
                        if stream {
                            let _ = frame_tx.send(
                                protocol::chunk_frame(req_id, &tokens, &stats)
                                    .to_string(),
                            );
                        }
                    }
                    Ok(GenEvent::Done(resp)) => {
                        // Free the id BEFORE the terminal frame goes out:
                        // a client may legitimately reuse its req_id the
                        // moment it reads `done`, and the duplicate check
                        // must not race that.
                        inflight.lock().unwrap().remove(&req_id);
                        let _ = frame_tx.send(
                            protocol::done_frame(req_id, &resp, !stream)
                                .to_string(),
                        );
                        break;
                    }
                    Err(_) => {
                        // Worker dropped the request (coordinator torn
                        // down before it ran): terminal error frame.
                        inflight.lock().unwrap().remove(&req_id);
                        let _ = frame_tx.send(
                            protocol::error_frame(req_id, "worker dropped request")
                                .to_string(),
                        );
                        break;
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::ModelFactory;
    use crate::models::sim::{SimModel, SimSpec};
    use crate::models::LogitModel;

    fn start_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let factory: ModelFactory = Arc::new(|| {
            let spec = SimSpec::new(64, 2.0, 0.5, 9);
            let (d, t) = SimModel::pair(spec);
            (
                Box::new(d) as Box<dyn LogitModel>,
                Box::new(t) as Box<dyn LogitModel>,
            )
        });
        let mut cfg = Config::new();
        cfg.server.workers = 2;
        cfg.engine.tree_budget = 8;
        let coord = Arc::new(Coordinator::start(cfg, factory));
        let server = Server::bind("127.0.0.1:0", coord).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let _ = server.run();
        });
        (addr, handle)
    }

    #[test]
    fn end_to_end_generate_stats_shutdown() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let tokens = client.generate(&[1, 2, 3], 12, 0.6).unwrap();
        assert_eq!(tokens.len(), 12);

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize(), Some(1));

        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn streamed_generate_over_tcp() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let mut chunks = 0usize;
        let (tokens, done) = client
            .generate_stream(7, &[1, 2, 3], &GenParams::simple(12, 0.6), |_| {
                chunks += 1;
            })
            .unwrap();
        assert_eq!(tokens.len(), 12);
        assert!(chunks >= 1);
        assert_eq!(done.finish().unwrap().name(), "length");
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn malformed_line_returns_error() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let reply = client.send_raw("this is not json").unwrap();
        assert!(reply.get("error").is_some());
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
}
