//! Blocking client for the line-JSON protocol — used by the examples, the
//! load-test driver and the `dyspec client` subcommand.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::json::{parse, Json};

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self {
            writer: stream,
            reader,
        })
    }

    /// Send one raw line, read one JSON reply.
    pub fn send_raw(&mut self, line: &str) -> Result<Json, String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| e.to_string())?;
        let mut reply = String::new();
        self.reader
            .read_line(&mut reply)
            .map_err(|e| e.to_string())?;
        parse(reply.trim()).map_err(|e| format!("bad reply: {e}"))
    }

    fn send(&mut self, msg: Json) -> Result<Json, String> {
        let reply = self.send_raw(&msg.to_string())?;
        if let Some(err) = reply.get("error").and_then(Json::as_str) {
            return Err(err.to_string());
        }
        Ok(reply)
    }

    /// Generate tokens for a prompt.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<Vec<u32>, String> {
        let msg = Json::obj(vec![
            (
                "prompt",
                Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
            ("temperature", Json::Num(temperature as f64)),
        ]);
        let reply = self.send(msg)?;
        reply
            .get("tokens")
            .and_then(Json::as_arr)
            .ok_or("reply missing tokens")?
            .iter()
            .map(|t| {
                t.as_usize()
                    .map(|v| v as u32)
                    .ok_or_else(|| "bad token".to_string())
            })
            .collect()
    }

    /// Full generation reply (includes timing fields).
    pub fn generate_detailed(
        &mut self,
        prompt: &[u32],
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<Json, String> {
        let msg = Json::obj(vec![
            (
                "prompt",
                Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
            ("temperature", Json::Num(temperature as f64)),
        ]);
        self.send(msg)
    }

    pub fn stats(&mut self) -> Result<Json, String> {
        self.send(Json::obj(vec![("cmd", Json::Str("stats".into()))]))
    }

    pub fn shutdown(&mut self) -> Result<(), String> {
        self.send(Json::obj(vec![("cmd", Json::Str("shutdown".into()))]))?;
        Ok(())
    }
}
