//! Blocking client for the line-JSON protocol — used by the examples, the
//! load-test driver and the `dyspec client` subcommand. Speaks both the
//! legacy one-shot surface and protocol v1 (enveloped, streamed,
//! cancellable); see `server/protocol.rs` for the frame grammar.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use super::protocol::{
    self, cancel_envelope, generate_envelope, parse_frame, Frame,
};
use crate::coordinator::GenParams;
use crate::util::json::{parse, Json};

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self {
            writer: stream,
            reader,
        })
    }

    /// Raw write half of the connection — for tests and drivers that
    /// need byte-level control over how envelopes hit the wire (the
    /// incremental decoder must not care).
    pub fn writer_mut(&mut self) -> &mut TcpStream {
        &mut self.writer
    }

    /// Send one raw line (no reply expected yet).
    pub fn send_line(&mut self, line: &str) -> Result<(), String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| e.to_string())
    }

    /// Read one reply line as JSON.
    pub fn read_json(&mut self) -> Result<Json, String> {
        let mut reply = String::new();
        let n = self
            .reader
            .read_line(&mut reply)
            .map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("server closed the connection".into());
        }
        parse(reply.trim()).map_err(|e| format!("bad reply: {e}"))
    }

    /// Read one reply line as a protocol-v1 [`Frame`].
    pub fn read_frame(&mut self) -> Result<Frame, String> {
        let mut reply = String::new();
        let n = self
            .reader
            .read_line(&mut reply)
            .map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("server closed the connection".into());
        }
        parse_frame(&reply)
    }

    /// Send one raw line, read one JSON reply.
    pub fn send_raw(&mut self, line: &str) -> Result<Json, String> {
        self.send_line(line)?;
        self.read_json()
    }

    fn send(&mut self, msg: Json) -> Result<Json, String> {
        let reply = self.send_raw(&msg.to_string())?;
        if let Some(err) = reply.get("error").and_then(Json::as_str) {
            return Err(err.to_string());
        }
        Ok(reply)
    }

    /// Submit a protocol-v1 generate envelope without waiting for frames
    /// (multiplexing: interleave with other submits, then read frames).
    pub fn submit(
        &mut self,
        req_id: u64,
        prompt: &[u32],
        params: &GenParams,
        stream: bool,
    ) -> Result<(), String> {
        self.send_line(&generate_envelope(req_id, prompt, params, stream).to_string())
    }

    /// Cancel an in-flight request (the stream's `done` frame, with
    /// `finish:"cancelled"`, is the acknowledgement).
    pub fn cancel(&mut self, req_id: u64) -> Result<(), String> {
        self.send_line(&cancel_envelope(req_id).to_string())
    }

    /// Streamed generation: submits with `stream:true`, invokes `on_chunk`
    /// per chunk frame, returns (concatenated tokens, done frame).
    /// Frames for other `req_id`s are an error here — use [`Client::submit`]
    /// + [`Client::read_frame`] directly for multiplexed flows.
    pub fn generate_stream<F: FnMut(&Frame)>(
        &mut self,
        req_id: u64,
        prompt: &[u32],
        params: &GenParams,
        mut on_chunk: F,
    ) -> Result<(Vec<u32>, Frame), String> {
        self.submit(req_id, prompt, params, true)?;
        let mut tokens = Vec::new();
        loop {
            let frame = self.read_frame()?;
            if frame.req_id != Some(req_id) {
                return Err(format!(
                    "unexpected frame for req {:?}",
                    frame.req_id
                ));
            }
            match frame.event.as_str() {
                "chunk" => {
                    tokens.extend(frame.tokens());
                    on_chunk(&frame);
                }
                "done" => return Ok((tokens, frame)),
                "error" => {
                    return Err(frame
                        .error()
                        .unwrap_or("unknown server error")
                        .to_string())
                }
                other => return Err(format!("unexpected event: {other}")),
            }
        }
    }

    /// Enveloped one-shot generation (v1, `stream:false`): single `done`
    /// frame carrying the full token array.
    pub fn generate_oneshot(
        &mut self,
        req_id: u64,
        prompt: &[u32],
        params: &GenParams,
    ) -> Result<(Vec<u32>, Frame), String> {
        self.submit(req_id, prompt, params, false)?;
        let frame = self.read_frame()?;
        if frame.req_id != Some(req_id) {
            return Err(format!("unexpected frame for req {:?}", frame.req_id));
        }
        match frame.event.as_str() {
            "done" => Ok((frame.tokens(), frame)),
            "error" => Err(frame
                .error()
                .unwrap_or("unknown server error")
                .to_string()),
            other => Err(format!("unexpected event: {other}")),
        }
    }

    /// Generate tokens for a prompt (legacy un-enveloped surface).
    pub fn generate(
        &mut self,
        prompt: &[u32],
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<Vec<u32>, String> {
        let reply =
            self.generate_detailed(prompt, max_new_tokens, temperature)?;
        reply
            .get("tokens")
            .and_then(Json::as_arr)
            .ok_or("reply missing tokens")?
            .iter()
            .map(|t| {
                t.as_usize()
                    .map(|v| v as u32)
                    .ok_or_else(|| "bad token".to_string())
            })
            .collect()
    }

    /// Full legacy generation reply (includes timing fields).
    pub fn generate_detailed(
        &mut self,
        prompt: &[u32],
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<Json, String> {
        let msg = Json::obj(vec![
            (
                "prompt",
                Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
            ("temperature", Json::Num(temperature as f64)),
        ]);
        self.send(msg)
    }

    pub fn stats(&mut self) -> Result<Json, String> {
        self.send(Json::obj(vec![("cmd", Json::Str("stats".into()))]))
    }

    /// Prometheus text exposition of the server's metrics snapshot plus
    /// the observatory series (stage quantiles, acceptance table). The
    /// multi-line text rides the line-JSON wire as one string field.
    pub fn metrics(&mut self) -> Result<String, String> {
        let reply =
            self.send(Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
        reply
            .get("prometheus")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| "reply missing prometheus text".into())
    }

    /// Flight-recorder dump: `{"tracing":…,"dropped":…,"spans":[…]}`.
    pub fn trace(&mut self) -> Result<Json, String> {
        self.send(Json::obj(vec![("cmd", Json::Str("trace".into()))]))
    }

    pub fn shutdown(&mut self) -> Result<(), String> {
        self.send(Json::obj(vec![("cmd", Json::Str("shutdown".into()))]))?;
        Ok(())
    }
}

// Re-exported for callers that only import the client module.
pub use protocol::PROTOCOL_VERSION;
