//! Readiness polling for the reactor transport (DESIGN.md §Transport).
//!
//! [`Poller`] is a minimal, std-only I/O event multiplexer. On Linux it
//! is a direct `extern "C"` binding to `epoll_create1` / `epoll_ctl` /
//! `epoll_wait` (level-triggered), with a self-pipe as the cross-thread
//! [`Waker`]. Everywhere else a portable fallback reports every
//! registered fd as ready on a short tick; the connection state machines
//! treat readiness as a hint and handle `WouldBlock`, so spurious
//! readiness costs a failed nonblocking syscall, never correctness.
//!
//! [`ReactorHandle`] is the cross-thread mailbox of one reactor thread:
//! worker-side event sinks push a connection id onto its dirty list and
//! wake the poller; the accept loop injects new connections the same
//! way. Both are drained at the top of every reactor iteration.

use std::io;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What a registered fd should be watched for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };

    pub fn rw(writable: bool) -> Interest {
        Interest {
            readable: true,
            writable,
        }
    }
}

/// One readiness report. EPOLLHUP/EPOLLERR fold into `readable`: the
/// read path observes the actual EOF/error and closes the connection.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// Token values `usize::MAX` (waker) and `usize::MAX - 1` (listener) are
/// reserved by the transport; connection ids stay far below them.
pub const LISTENER_TOKEN: usize = usize::MAX - 1;
const WAKE_TOKEN: usize = usize::MAX;

#[cfg(target_os = "linux")]
pub use epoll::{Poller, Waker};
#[cfg(not(target_os = "linux"))]
pub use tick::{Poller, Waker};

/// Raw fd of a socket, for [`Poller`] registration. On non-unix targets
/// the tick poller never dereferences it.
#[cfg(unix)]
pub fn raw_fd<T: std::os::unix::io::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}

#[cfg(not(unix))]
pub fn raw_fd<T>(_t: &T) -> i32 {
    -1
}

/// Cross-thread mailbox of one reactor thread. Shared with every
/// connection sink the thread's connections hand to workers.
pub struct ReactorHandle {
    /// Connections with pending outbox work (worker pushed frames, a
    /// legacy request finished, or the outbox overflowed).
    dirty: Mutex<Vec<u64>>,
    /// Freshly accepted connections assigned to this reactor.
    inject: Mutex<Vec<(u64, TcpStream)>>,
    waker: Waker,
}

impl ReactorHandle {
    pub fn new(waker: Waker) -> Arc<Self> {
        Arc::new(Self {
            dirty: Mutex::new(Vec::new()),
            inject: Mutex::new(Vec::new()),
            waker,
        })
    }

    /// Mark a connection as having pending outbound work and wake the
    /// reactor. Called from worker threads (event sinks).
    pub fn notify_dirty(&self, conn_id: u64) {
        self.dirty.lock().unwrap().push(conn_id);
        self.waker.wake();
    }

    /// Hand a new connection to this reactor. Called from the accept
    /// loop (reactor thread 0).
    pub fn inject(&self, conn_id: u64, stream: TcpStream) {
        self.inject.lock().unwrap().push((conn_id, stream));
        self.waker.wake();
    }

    pub fn wake(&self) {
        self.waker.wake();
    }

    pub fn take_dirty(&self) -> Vec<u64> {
        std::mem::take(&mut *self.dirty.lock().unwrap())
    }

    pub fn take_injected(&self) -> Vec<(u64, TcpStream)> {
        std::mem::take(&mut *self.inject.lock().unwrap())
    }
}

/// Linux: level-triggered epoll + a nonblocking self-pipe waker.
#[cfg(target_os = "linux")]
mod epoll {
    use super::{Event, Interest, WAKE_TOKEN};
    use std::io;
    use std::sync::Arc;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const O_NONBLOCK: i32 = 0o4000;
    const O_CLOEXEC: i32 = 0o2000000;

    /// `struct epoll_event`; packed on x86-64 exactly as the kernel ABI
    /// demands (`__EPOLL_PACKED`), natural layout elsewhere.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        fn close(fd: i32) -> i32;
        fn pipe2(fds: *mut i32, flags: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }

    fn cvt(ret: i32) -> io::Result<i32> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    fn interest_bits(interest: Interest) -> u32 {
        let mut bits = 0;
        if interest.readable {
            bits |= EPOLLIN | EPOLLRDHUP;
        }
        if interest.writable {
            bits |= EPOLLOUT;
        }
        bits
    }

    /// Write end of the self-pipe, closed when the last waker drops.
    struct WakeFd(i32);

    impl Drop for WakeFd {
        fn drop(&mut self) {
            unsafe { close(self.0) };
        }
    }

    /// Wakes the owning [`Poller`] out of `wait` from any thread.
    #[derive(Clone)]
    pub struct Waker(Arc<WakeFd>);

    impl Waker {
        pub fn wake(&self) {
            // A full pipe already guarantees a pending wakeup; every
            // other failure mode is ignorable for a wake signal.
            let byte = 1u8;
            unsafe { write(self.0 .0, &byte, 1) };
        }
    }

    pub struct Poller {
        epfd: i32,
        wake_read: i32,
        waker: Waker,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            let mut fds = [0i32; 2];
            if let Err(e) =
                cvt(unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) })
            {
                unsafe { close(epfd) };
                return Err(e);
            }
            let poller = Poller {
                epfd,
                wake_read: fds[0],
                waker: Waker(Arc::new(WakeFd(fds[1]))),
            };
            poller.ctl(EPOLL_CTL_ADD, fds[0], WAKE_TOKEN, Interest::READ)?;
            Ok(poller)
        }

        fn ctl(
            &self,
            op: i32,
            fd: i32,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: interest_bits(interest),
                data: token as u64,
            };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn register(
            &mut self,
            fd: i32,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn reregister(
            &mut self,
            fd: i32,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: i32, _token: usize) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::READ)
        }

        pub fn waker(&self) -> Waker {
            self.waker.clone()
        }

        /// Wait up to `timeout` and append readiness events. A wakeup or
        /// signal interruption returns with no events — callers treat an
        /// empty batch as "check your mailboxes".
        pub fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout: Duration,
        ) -> io::Result<()> {
            let mut evs = [EpollEvent { events: 0, data: 0 }; 64];
            let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let n = unsafe {
                epoll_wait(self.epfd, evs.as_mut_ptr(), evs.len() as i32, ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in evs.iter().take(n as usize) {
                // Copy out of the (possibly packed) struct before use.
                let bits = { ev.events };
                let data = { ev.data };
                if data == WAKE_TOKEN as u64 {
                    self.drain_wake_pipe();
                    continue;
                }
                out.push(Event {
                    token: data as usize,
                    readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR)
                        != 0,
                    writable: bits & EPOLLOUT != 0,
                });
            }
            Ok(())
        }

        fn drain_wake_pipe(&self) {
            let mut buf = [0u8; 64];
            loop {
                let n = unsafe {
                    read(self.wake_read, buf.as_mut_ptr(), buf.len())
                };
                if n <= 0 {
                    break; // drained (EAGAIN) or pipe gone
                }
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.wake_read);
                close(self.epfd);
            }
        }
    }
}

/// Portable fallback: no syscall multiplexer. `wait` sleeps a short tick
/// (cut short by a pending wake) and reports every registered fd as
/// ready for whatever it is interested in; the nonblocking state
/// machines absorb the spurious readiness.
#[cfg(not(target_os = "linux"))]
mod tick {
    use super::{Event, Interest};
    use std::io;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    const TICK: Duration = Duration::from_millis(2);

    #[derive(Clone)]
    pub struct Waker(Arc<AtomicBool>);

    impl Waker {
        pub fn wake(&self) {
            self.0.store(true, Ordering::SeqCst);
        }
    }

    pub struct Poller {
        registered: Vec<(i32, usize, Interest)>,
        woken: Arc<AtomicBool>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                registered: Vec::new(),
                woken: Arc::new(AtomicBool::new(false)),
            })
        }

        pub fn register(
            &mut self,
            fd: i32,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            self.registered.push((fd, token, interest));
            Ok(())
        }

        pub fn reregister(
            &mut self,
            fd: i32,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            self.registered.retain(|&(_, t, _)| t != token);
            self.registered.push((fd, token, interest));
            Ok(())
        }

        pub fn deregister(&mut self, _fd: i32, token: usize) -> io::Result<()> {
            self.registered.retain(|&(_, t, _)| t != token);
            Ok(())
        }

        pub fn waker(&self) -> Waker {
            Waker(self.woken.clone())
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout: Duration,
        ) -> io::Result<()> {
            if !self.woken.swap(false, Ordering::SeqCst) {
                std::thread::sleep(timeout.min(TICK));
                self.woken.store(false, Ordering::SeqCst);
            }
            for &(_, token, interest) in &self.registered {
                out.push(Event {
                    token,
                    readable: interest.readable,
                    writable: interest.writable,
                });
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    fn socket_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn readable_after_peer_writes() {
        let (mut a, b) = socket_pair();
        b.set_nonblocking(true).unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(raw_fd(&b), 7, Interest::READ).unwrap();

        a.write_all(b"ping").unwrap();
        a.flush().unwrap();

        let mut events = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut got = false;
        while std::time::Instant::now() < deadline && !got {
            events.clear();
            poller
                .wait(&mut events, Duration::from_millis(100))
                .unwrap();
            got = events.iter().any(|e| e.token == 7 && e.readable);
        }
        assert!(got, "peer write never reported readable");
        // The tick fallback reports readiness optimistically; retry the
        // nonblocking read until the bytes are actually there.
        let mut buf = [0u8; 8];
        let mut c = &b;
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match c.read(&mut buf) {
                Ok(n) => {
                    assert_eq!(n, 4);
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    assert!(std::time::Instant::now() < deadline);
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("read failed: {e}"),
            }
        }
        poller.deregister(raw_fd(&b), 7).unwrap();
    }

    #[test]
    fn waker_interrupts_wait_from_another_thread() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            waker.wake();
        });
        let t0 = std::time::Instant::now();
        let mut events = Vec::new();
        // Without the wake this would sleep the full 10 s (linux); the
        // tick fallback returns early anyway, which also passes.
        poller.wait(&mut events, Duration::from_secs(10)).unwrap();
        while t0.elapsed() < Duration::from_millis(40) {
            events.clear();
            poller.wait(&mut events, Duration::from_secs(10)).unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_secs(9),
            "wake did not interrupt wait"
        );
        handle.join().unwrap();
    }

    #[test]
    fn write_interest_toggles() {
        let (_a, b) = socket_pair();
        b.set_nonblocking(true).unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(raw_fd(&b), 3, Interest::rw(true)).unwrap();
        let mut events = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut writable = false;
        while std::time::Instant::now() < deadline && !writable {
            events.clear();
            poller
                .wait(&mut events, Duration::from_millis(100))
                .unwrap();
            writable = events.iter().any(|e| e.token == 3 && e.writable);
        }
        assert!(writable, "idle socket never writable");
        // Drop write interest: subsequent batches stop reporting it.
        poller.reregister(raw_fd(&b), 3, Interest::rw(false)).unwrap();
        events.clear();
        poller.wait(&mut events, Duration::from_millis(50)).unwrap();
        assert!(events.iter().all(|e| e.token != 3 || !e.writable));
    }

    #[test]
    fn reactor_handle_mailboxes() {
        let poller = Poller::new().unwrap();
        let handle = ReactorHandle::new(poller.waker());
        handle.notify_dirty(4);
        handle.notify_dirty(9);
        assert_eq!(handle.take_dirty(), vec![4, 9]);
        assert!(handle.take_dirty().is_empty());
        let (a, _b) = socket_pair();
        handle.inject(11, a);
        let injected = handle.take_injected();
        assert_eq!(injected.len(), 1);
        assert_eq!(injected[0].0, 11);
    }
}
