//! Per-connection state machine for the reactor transport (DESIGN.md
//! §Transport).
//!
//! One [`Conn`] owns a nonblocking socket end to end: an incremental
//! [`FrameDecoder`] on the read side (bytes in → protocol lines out, no
//! `BufRead::read_line`), and on the write side a bounded shared outbox
//! of serialized frames plus the partially-written front frame. The
//! reactor thread that owns the connection drives both directions from
//! readiness events; worker threads never touch the socket — their
//! [`ConnSink`] serializes each [`GenEvent`] into a wire frame, pushes
//! it into the outbox and wakes the reactor.
//!
//! Disconnects are observed, not polled: a nonblocking read returning 0
//! (or a failed write) cancels every in-flight request of the
//! connection — the reactor-EOF replacement for the old destructive
//! `peek`-polling `peer_gone` loop. Backpressure is bounded the same
//! way: a client that stops draining its socket until the outbox cap is
//! hit is treated as gone (requests cancelled, connection closed,
//! `backpressure_closed` counted) rather than buffered without limit.
//!
//! Legacy un-enveloped generates keep their v0 contract — one blocking
//! one-shot reply each, replies in submission order — via a per-
//! connection FIFO: at most one legacy request is in flight at a time,
//! the next one is submitted when its predecessor's reply frame is
//! queued. Enveloped v1 traffic (including cancels) flows concurrently,
//! which the old transport could not do while a legacy wait blocked its
//! reader thread.
//!
//! Submission goes through the router tier: `try_submit_sink` places
//! each request on a per-worker queue (prefix-affinity by default), and
//! the router's `RoutedSink` wraps this connection's [`ConnSink`]
//! transparently — frames are forwarded byte-for-byte while the shard's
//! queued/inflight gauges track the request lifecycle. A worker killed
//! mid-request settles the stream with a `finish=cancelled` done frame
//! through the same path.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::protocol::{self, ClientMessage};
use super::reactor::{raw_fd, ReactorHandle, Waker};
use crate::coordinator::{
    CancelToken, Coordinator, EventSink, GenEvent, GenParams, Metrics,
};
use crate::log_debug;
use crate::util::json::{parse as parse_json, Json};

/// Server-wide context a connection needs while handling traffic.
pub struct TransportCtl {
    pub coord: Arc<Coordinator>,
    /// Accept-loop + reactor stop flag (`{"cmd":"shutdown"}` sets it).
    pub stop: Arc<AtomicBool>,
    /// Every reactor's waker, so a shutdown observed on any connection
    /// reaches all event loops immediately.
    pub wakers: Vec<Waker>,
}

impl TransportCtl {
    fn metrics(&self) -> &Metrics {
        &self.coord.metrics
    }
}

/// The halves of a connection shared with worker-side sinks: the
/// bounded frame outbox, the in-flight request map, and the flags the
/// reactor polls on its dirty pass.
pub struct ConnShared {
    pub id: u64,
    /// Serialized, newline-terminated wire frames. Stored as raw bytes so
    /// the writer never re-encodes: flushing coalesces queued frames into
    /// one buffer and hands the kernel a single `write` per pump.
    outbox: Mutex<VecDeque<Vec<u8>>>,
    outbox_cap: usize,
    /// A frame push found the outbox full: the client is not draining
    /// its socket — the reactor tears the connection down.
    overflowed: AtomicBool,
    /// Reactor closed the connection; sinks drop events silently.
    closed: AtomicBool,
    /// The active legacy request queued its terminal reply; the reactor
    /// submits the next one from the FIFO.
    legacy_finished: AtomicBool,
    /// Client req_id → cancel token for every in-flight v1 request.
    inflight: Mutex<HashMap<u64, CancelToken>>,
    reactor: Arc<ReactorHandle>,
    metrics: Arc<Metrics>,
}

impl ConnShared {
    pub fn new(
        id: u64,
        outbox_cap: usize,
        reactor: Arc<ReactorHandle>,
        metrics: Arc<Metrics>,
    ) -> Arc<Self> {
        Arc::new(Self {
            id,
            outbox: Mutex::new(VecDeque::new()),
            outbox_cap: outbox_cap.max(1),
            overflowed: AtomicBool::new(false),
            closed: AtomicBool::new(false),
            legacy_finished: AtomicBool::new(false),
            inflight: Mutex::new(HashMap::new()),
            reactor,
            metrics,
        })
    }

    /// Queue one serialized frame (newline-terminated here, once — the
    /// write path appends nothing). Returns false when the connection is
    /// closed or the outbox is at capacity (the overflow flag is set and
    /// the reactor will close the connection — bounded memory beats an
    /// unbounded buffer to a client that stopped reading).
    fn push_frame(&self, line: String) -> bool {
        let mut outbox = self.outbox.lock().unwrap();
        // The closed check must happen under the outbox lock: `close()`
        // drains the outbox (and its gauge contribution) under the same
        // lock, so a racing push either lands before the drain (and is
        // drained with the rest) or observes `closed` — never leaks a
        // frame into a swept connection.
        if self.closed.load(Ordering::SeqCst) {
            return false;
        }
        if outbox.len() >= self.outbox_cap {
            self.overflowed.store(true, Ordering::SeqCst);
            false
        } else {
            let mut frame = line.into_bytes();
            frame.push(b'\n');
            outbox.push_back(frame);
            self.metrics.outbox_inc();
            true
        }
    }

    /// Pop a single frame (tests only — the write path drains bursts via
    /// [`ConnShared::drain_into`]).
    #[cfg(test)]
    fn pop_frame(&self) -> Option<Vec<u8>> {
        let frame = self.outbox.lock().unwrap().pop_front();
        if frame.is_some() {
            self.metrics.outbox_dec(1);
        }
        frame
    }

    /// Drain queued frames into `buf` until it reaches `limit` bytes (the
    /// first frame always moves, without a copy, when `buf` is empty).
    /// One lock acquisition and one gauge update cover the whole burst —
    /// the coalesced write must not trade its saved syscall for N mutex
    /// round-trips against the worker threads pushing frames. Returns how
    /// many frames were taken.
    fn drain_into(&self, buf: &mut Vec<u8>, limit: usize) -> usize {
        let mut taken = 0u64;
        {
            let mut outbox = self.outbox.lock().unwrap();
            while buf.len() < limit {
                let Some(frame) = outbox.pop_front() else {
                    break;
                };
                if buf.is_empty() {
                    *buf = frame;
                } else {
                    buf.extend_from_slice(&frame);
                }
                taken += 1;
            }
        }
        if taken > 0 {
            self.metrics.outbox_dec(taken);
        }
        taken as usize
    }

    fn outbox_len(&self) -> usize {
        self.outbox.lock().unwrap().len()
    }

    fn at_capacity(&self) -> bool {
        self.outbox.lock().unwrap().len() >= self.outbox_cap
    }

    fn outbox_cap(&self) -> usize {
        self.outbox_cap
    }

    fn notify(&self) {
        self.reactor.notify_dirty(self.id);
    }

    /// Mark closed and drain the outbox (adjusting the frame gauge);
    /// subsequent pushes are refused.
    fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let drained = {
            let mut outbox = self.outbox.lock().unwrap();
            let n = outbox.len();
            outbox.clear();
            n
        };
        self.metrics.outbox_dec(drained as u64);
    }
}

/// Worker-side event sink of one request: serializes events into wire
/// frames, pushes them into the connection outbox and wakes the reactor
/// — the replacement for the per-request forwarder thread.
pub struct ConnSink {
    req_id: u64,
    stream: bool,
    /// Legacy un-enveloped request: the terminal event becomes the v0
    /// one-shot reply object and advances the connection's legacy FIFO.
    legacy: bool,
    shared: Arc<ConnShared>,
    /// Set once the request was accepted by the admission queue — a sink
    /// dropped before that (validation / queue-full rejection) must stay
    /// silent, because the submitter already sent the error frame.
    admitted: Arc<AtomicBool>,
    done_sent: AtomicBool,
    /// Trace id minted at admission (0 = tracing off). The queue stores
    /// it via `attach_trace` BEFORE the request is enqueued, so even the
    /// first chunk frame — which may race the submitter's return — sees
    /// it. At 0 the frames are bit-identical to an untraced server
    /// (`protocol::with_trace` is the identity there).
    trace: AtomicU64,
}

impl ConnSink {
    fn new(
        req_id: u64,
        stream: bool,
        legacy: bool,
        shared: Arc<ConnShared>,
        admitted: Arc<AtomicBool>,
    ) -> Self {
        Self {
            req_id,
            stream,
            legacy,
            shared,
            admitted,
            done_sent: AtomicBool::new(false),
            trace: AtomicU64::new(0),
        }
    }

    fn finish(&self, line: String) -> bool {
        self.done_sent.store(true, Ordering::SeqCst);
        if self.legacy {
            let ok = self.shared.push_frame(line);
            self.shared.legacy_finished.store(true, Ordering::SeqCst);
            ok
        } else {
            // Free the id BEFORE the terminal frame can reach the
            // client: it may legitimately reuse its req_id the moment it
            // reads `done`, and the duplicate check must not race that.
            self.shared.inflight.lock().unwrap().remove(&self.req_id);
            self.shared.push_frame(line)
        }
    }
}

impl EventSink for ConnSink {
    fn attach_trace(&self, trace: u64) {
        self.trace.store(trace, Ordering::SeqCst);
    }

    fn send(&self, ev: GenEvent) -> bool {
        if self.shared.closed.load(Ordering::SeqCst) {
            return false;
        }
        let trace = self.trace.load(Ordering::SeqCst);
        let pushed = match ev {
            GenEvent::Chunk { tokens, stats } => {
                if self.stream && !self.legacy {
                    self.shared.push_frame(
                        protocol::with_trace(
                            protocol::chunk_frame(self.req_id, &tokens, &stats),
                            trace,
                        )
                        .to_string(),
                    )
                } else {
                    // One-shot surfaces only want the terminal frame.
                    true
                }
            }
            GenEvent::Done(resp) => {
                let line = if self.legacy {
                    protocol::response_json(&resp).to_string()
                } else {
                    protocol::with_trace(
                        protocol::done_frame(self.req_id, &resp, !self.stream),
                        trace,
                    )
                    .to_string()
                };
                self.finish(line)
            }
        };
        self.shared.notify();
        pushed
    }
}

impl Drop for ConnSink {
    /// An admitted request dropped without its `Done` (coordinator torn
    /// down mid-flight) still terminates its stream — the sink itself
    /// emits the terminal error frame the forwarder thread used to send
    /// on a disconnected channel.
    fn drop(&mut self) {
        if !self.admitted.load(Ordering::SeqCst)
            || self.done_sent.load(Ordering::SeqCst)
        {
            return;
        }
        let line = if self.legacy {
            protocol::error_json("worker dropped request").to_string()
        } else {
            protocol::with_trace(
                protocol::error_frame(self.req_id, "worker dropped request"),
                self.trace.load(Ordering::SeqCst),
            )
            .to_string()
        };
        self.finish(line);
        self.shared.notify();
    }
}

/// Ordered per-connection work the v0 reply contract depends on: the
/// blocking transport answered every un-keyed line (legacy generates,
/// parse errors, stats) strictly in submission order, so while legacy
/// work is pending, later un-keyed replies queue behind it instead of
/// overtaking on the wire (v1 frames are `req_id`-keyed and exempt).
enum LegacyItem {
    Generate(Vec<u32>, GenParams),
    /// A pre-serialized un-keyed reply (parse-error object,
    /// pipeline-full error).
    Reply(String),
    /// Stats snapshot — serialized at emission time, so the counters
    /// are as fresh as the blocking transport's (which only snapshotted
    /// after the preceding generates finished).
    Stats,
    /// Prometheus exposition of the metrics snapshot plus the
    /// observatory's stage/acceptance series — rendered at emission
    /// time, same freshness argument as `Stats`.
    Metrics,
    /// Flight-recorder span dump (`{"cmd":"trace"}`) — emission-time
    /// too, so the reply reflects rounds recorded up to this frame.
    Trace,
}

/// One connection, owned and driven by exactly one reactor thread.
pub struct Conn {
    stream: TcpStream,
    peer: String,
    decoder: protocol::FrameDecoder,
    shared: Arc<ConnShared>,
    /// Front frame currently being written, and how much of it went out.
    partial: Vec<u8>,
    written: usize,
    /// Un-keyed work not yet performed (FIFO preserves v0's
    /// submission-order replies) and the cancel token of the legacy
    /// generate in flight.
    legacy_queue: VecDeque<LegacyItem>,
    legacy_active: Option<CancelToken>,
    /// Flush what is queued, then close (protocol violation path).
    closing: bool,
    /// Closed: awaiting sweep by the reactor loop.
    pub closed: bool,
    /// Write-interest currently registered with the poller.
    pub registered_write: bool,
}

impl Conn {
    pub fn new(stream: TcpStream, shared: Arc<ConnShared>) -> Self {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        Self {
            stream,
            peer,
            decoder: protocol::FrameDecoder::default(),
            shared,
            partial: Vec::new(),
            written: 0,
            legacy_queue: VecDeque::new(),
            legacy_active: None,
            closing: false,
            closed: false,
            registered_write: false,
        }
    }

    pub fn fd(&self) -> i32 {
        raw_fd(&self.stream)
    }

    /// Does the poller need to watch this socket for writability?
    pub fn wants_write(&self) -> bool {
        self.written < self.partial.len() || self.shared.outbox_len() > 0
    }

    /// Readiness: drain the socket, feed the decoder, handle every
    /// complete line. EOF or a read error closes the connection and
    /// cancels its in-flight work.
    ///
    /// A `closing` connection (protocol violation, flushing its error
    /// reply) still reads — and discards — inbound bytes: leaving them
    /// unread would make level-triggered epoll report the fd forever
    /// (a busy-spin a hostile peer could provoke for free), and reading
    /// is also how the peer's EOF/reset is observed while we wait for
    /// the outbox to drain.
    ///
    /// The per-call read budget is the fairness bound: one firehose
    /// peer yields the reactor back after ~256 KB and level-triggered
    /// polling resumes it next iteration, instead of starving every
    /// other connection on the thread.
    pub fn on_readable(&mut self, ctl: &TransportCtl) {
        let mut buf = [0u8; 16 * 1024];
        for _ in 0..16 {
            if self.closed {
                return;
            }
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.close(ctl, "peer closed");
                    return;
                }
                Ok(n) => {
                    if !self.closing {
                        self.decoder.push(&buf[..n]);
                        self.drain_lines(ctl);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.close(ctl, "read error");
                    return;
                }
            }
        }
    }

    fn drain_lines(&mut self, ctl: &TransportCtl) {
        loop {
            match self.decoder.next_line() {
                Ok(Some(line)) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    self.handle_line(ctl, &line);
                    if self.closed || self.closing {
                        return;
                    }
                }
                Ok(None) => return,
                Err(e) => {
                    // Framing is unrecoverable: tell the peer (legacy
                    // error object — there is no attributable req_id in
                    // a broken byte stream), flush, close.
                    self.push(ctl, protocol::error_json(&e.to_string()).to_string());
                    self.closing = true;
                    return;
                }
            }
        }
    }

    fn handle_line(&mut self, ctl: &TransportCtl, line: &str) {
        match protocol::parse_client_message(line) {
            Ok(ClientMessage::Generate {
                req_id: Some(req_id),
                prompt,
                params,
                stream,
            }) => self.submit_v1(ctl, req_id, prompt, params, stream),
            Ok(ClientMessage::Generate {
                req_id: None,
                prompt,
                params,
                ..
            }) => {
                // Bounded pipeline: the blocking transport implicitly
                // throttled pipelined v0 clients through the kernel
                // recv buffer (its reader was parked on the active
                // generate); the reactor reads eagerly, so the FIFO
                // needs an explicit cap — each queued request owes one
                // reply frame, so the outbox cap is the natural bound.
                if self.legacy_queue.len() >= self.shared.outbox_cap() {
                    self.reply_unkeyed(
                        ctl,
                        LegacyItem::Reply(
                            protocol::error_json("legacy pipeline full")
                                .to_string(),
                        ),
                    );
                } else {
                    self.legacy_queue
                        .push_back(LegacyItem::Generate(prompt, params));
                    self.advance_legacy(ctl);
                }
            }
            Ok(ClientMessage::Cancel { req_id }) => {
                // Fire-and-forget and idempotent: the request's own
                // `done` frame (finish:"cancelled") is the
                // acknowledgement; an unknown/finished id is a silent
                // no-op (a second terminal frame would violate the
                // exactly-one-done|error stream contract).
                if let Some(token) =
                    self.shared.inflight.lock().unwrap().get(&req_id)
                {
                    token.cancel();
                }
            }
            Ok(ClientMessage::Stats) => {
                self.reply_unkeyed(ctl, LegacyItem::Stats);
            }
            Ok(ClientMessage::Metrics) => {
                self.reply_unkeyed(ctl, LegacyItem::Metrics);
            }
            Ok(ClientMessage::Trace) => {
                self.reply_unkeyed(ctl, LegacyItem::Trace);
            }
            Ok(ClientMessage::Shutdown) => {
                self.push(ctl, protocol::ok_json().to_string());
                ctl.stop.store(true, Ordering::SeqCst);
                for waker in &ctl.wakers {
                    waker.wake();
                }
            }
            Err(e) => {
                // Attribute the failure to the envelope's req_id when
                // one is recoverable, so the submitter's stream still
                // gets its terminal frame — UNLESS that id is currently
                // in flight: a healthy stream must never receive a
                // second terminal frame (the malformed line was not a
                // valid submission for it), so such errors fall back to
                // the un-attributed legacy object, as does any line
                // with no readable req_id.
                let req_id = parse_json(line).ok().and_then(|doc| {
                    doc.get("req_id")
                        .and_then(Json::as_f64)
                        .map(|v| v as u64)
                });
                let attributable = match req_id {
                    Some(rid) => {
                        !self.shared.inflight.lock().unwrap().contains_key(&rid)
                    }
                    None => false,
                };
                if attributable {
                    let rid = req_id.expect("attributable implies some id");
                    self.push(ctl, protocol::error_frame(rid, &e).to_string());
                } else {
                    self.reply_unkeyed(
                        ctl,
                        LegacyItem::Reply(protocol::error_json(&e).to_string()),
                    );
                }
            }
        }
    }

    fn submit_v1(
        &mut self,
        ctl: &TransportCtl,
        req_id: u64,
        prompt: Vec<u32>,
        params: GenParams,
        stream: bool,
    ) {
        // The map lock is held across admission so a racing terminal
        // event (sink-side removal) cannot interleave with the insert.
        let mut map = self.shared.inflight.lock().unwrap();
        if map.contains_key(&req_id) {
            drop(map);
            self.push(
                ctl,
                protocol::error_frame(req_id, "req_id already in flight")
                    .to_string(),
            );
            return;
        }
        let admitted = Arc::new(AtomicBool::new(false));
        let sink = ConnSink::new(
            req_id,
            stream,
            false,
            self.shared.clone(),
            admitted.clone(),
        );
        match ctl
            .coord
            .try_submit_sink(prompt, params, Box::new(sink))
        {
            Ok((_id, cancel)) => {
                admitted.store(true, Ordering::SeqCst);
                map.insert(req_id, cancel);
            }
            Err(e) => {
                drop(map);
                self.push(ctl, protocol::error_frame(req_id, &e).to_string());
            }
        }
    }

    /// Work through the un-keyed FIFO: emit queued replies, submit the
    /// next legacy generate once the active one has queued its reply —
    /// at most one in flight per connection, so pipelined v0 clients
    /// read every un-keyed reply in submission order.
    fn advance_legacy(&mut self, ctl: &TransportCtl) {
        if self.shared.legacy_finished.swap(false, Ordering::SeqCst) {
            self.legacy_active = None;
        }
        while self.legacy_active.is_none() && !self.closed {
            let Some(item) = self.legacy_queue.pop_front() else {
                break;
            };
            let (prompt, params) = match item {
                LegacyItem::Generate(prompt, params) => (prompt, params),
                other => {
                    self.emit_unkeyed(ctl, other);
                    continue;
                }
            };
            let admitted = Arc::new(AtomicBool::new(false));
            let sink = ConnSink::new(
                0,
                false,
                true,
                self.shared.clone(),
                admitted.clone(),
            );
            match ctl.coord.try_submit_sink(prompt, params, Box::new(sink)) {
                Ok((_id, cancel)) => {
                    admitted.store(true, Ordering::SeqCst);
                    self.legacy_active = Some(cancel);
                }
                Err(e) => {
                    // This item's own reply — at the head, so in order.
                    self.push(ctl, protocol::error_json(&e).to_string());
                }
            }
        }
    }

    /// Answer an un-keyed line (stats, parse error, pipeline-full).
    /// While legacy work is pending the reply queues behind it in the
    /// FIFO (v0's line-order contract); otherwise it goes straight to
    /// the outbox. The FIFO fallback stays bounded: past twice the
    /// outbox cap the reply skips the queue — a flood degrades ordering
    /// (for the flooder alone) rather than growing memory.
    fn reply_unkeyed(&mut self, ctl: &TransportCtl, item: LegacyItem) {
        let legacy_pending =
            self.legacy_active.is_some() || !self.legacy_queue.is_empty();
        if legacy_pending
            && self.legacy_queue.len() < 2 * self.shared.outbox_cap()
        {
            self.legacy_queue.push_back(item);
        } else {
            self.emit_unkeyed(ctl, item);
        }
    }

    /// Serialize and queue one non-generate FIFO item's reply now.
    fn emit_unkeyed(&mut self, ctl: &TransportCtl, item: LegacyItem) {
        match item {
            LegacyItem::Reply(line) => self.push(ctl, line),
            LegacyItem::Stats => {
                let snap = ctl.metrics().snapshot().to_string();
                self.push(ctl, snap);
            }
            LegacyItem::Metrics => {
                // The exposition text is multi-line; the line-JSON wire
                // carries it as a single string field the client unwraps.
                let line = Json::obj(vec![(
                    "prometheus",
                    Json::Str(ctl.coord.prometheus()),
                )])
                .to_string();
                self.push(ctl, line);
            }
            LegacyItem::Trace => {
                let line = ctl.coord.trace_json().to_string();
                self.push(ctl, line);
            }
            LegacyItem::Generate(..) => {
                unreachable!("generate items are submitted, not emitted")
            }
        }
    }

    /// Reactor-side reply push (stats snapshots, error frames). Unlike
    /// worker-side sinks, the reactor owns the socket, so a full outbox
    /// is first given a chance to drain; if the socket is blocked AND
    /// the outbox is at capacity, the reply cannot be delivered within
    /// the buffering bound — dropping it silently would violate the
    /// exactly-one-terminal-frame contract, so the connection is torn
    /// down instead (the peer sees EOF, not a missing reply).
    fn push(&mut self, ctl: &TransportCtl, line: String) {
        if self.closed {
            return;
        }
        if self.shared.at_capacity() {
            self.pump_out(ctl);
        }
        if !self.shared.push_frame(line) && !self.closed {
            ctl.metrics().on_backpressure_closed();
            self.close(ctl, "outbox overflow (reactor reply)");
        }
    }

    /// Dirty pass: worker pushed frames, a legacy request finished, or
    /// the outbox overflowed.
    pub fn on_dirty(&mut self, ctl: &TransportCtl) {
        if self.closed {
            return;
        }
        if self.shared.overflowed.load(Ordering::SeqCst) {
            ctl.metrics().on_backpressure_closed();
            self.close(ctl, "outbox overflow (client not draining)");
            return;
        }
        self.advance_legacy(ctl);
        self.pump_out(ctl);
    }

    /// Cap on how many queued bytes one load coalesces into the write
    /// buffer. Big enough to turn a burst of chunk frames into a single
    /// `write`, small enough that one connection's flush cannot hold the
    /// reactor thread for an unbounded memcpy.
    const COALESCE_BYTES: usize = 64 * 1024;

    /// Make the partial-write buffer non-empty: keep the half-written
    /// front buffer, or coalesce queued outbox frames (already
    /// newline-terminated byte vectors) into one buffer so the pump
    /// issues a single `write` for the whole burst. Returns false when
    /// there is nothing left to write — shared by the nonblocking pump
    /// and the shutdown flush.
    fn load_partial(&mut self) -> bool {
        if self.written < self.partial.len() {
            return true;
        }
        self.partial.clear();
        self.written = 0;
        // First frame moves without a copy; further queued frames append
        // until the coalesce cap so one syscall covers the burst — all
        // under a single outbox lock (`ConnShared::drain_into`).
        self.shared
            .drain_into(&mut self.partial, Self::COALESCE_BYTES)
            > 0
    }

    /// Write until the socket would block or everything queued went out.
    pub fn pump_out(&mut self, ctl: &TransportCtl) {
        while !self.closed {
            if !self.load_partial() {
                break;
            }
            match self.stream.write(&self.partial[self.written..]) {
                Ok(0) => {
                    self.close(ctl, "write returned zero");
                    return;
                }
                Ok(n) => self.written += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.close(ctl, "write error");
                    return;
                }
            }
        }
        if self.closing && !self.closed {
            self.close(ctl, "protocol violation");
        }
    }

    /// Best-effort blocking flush for server shutdown: the reply to
    /// `{"cmd":"shutdown"}` (and anything else queued) should reach the
    /// peer before the event loop exits. Bounded twice over: a per-write
    /// timeout for a fully-stalled peer AND an overall deadline, so a
    /// trickle-reading peer cannot hold shutdown hostage one byte at a
    /// time.
    pub fn flush_blocking(&mut self, ctl: &TransportCtl) {
        if self.closed {
            return;
        }
        let _ = self.stream.set_nonblocking(false);
        let _ = self
            .stream
            .set_write_timeout(Some(Duration::from_millis(250)));
        let deadline = Instant::now() + Duration::from_secs(2);
        while self.load_partial() && Instant::now() < deadline {
            match self.stream.write(&self.partial[self.written..]) {
                Ok(n) if n > 0 => self.written += n,
                _ => break,
            }
        }
        self.close(ctl, "server shutdown");
    }

    /// Tear the connection down: every in-flight request (v1 and legacy)
    /// is cancelled so scheduler slots and KV residency free up within
    /// one speculation round, queued-but-unsubmitted legacy work is
    /// dropped, and worker sinks go quiet. The reactor loop sweeps the
    /// struct and deregisters the fd afterwards.
    pub fn close(&mut self, ctl: &TransportCtl, why: &str) {
        if self.closed {
            return;
        }
        self.closed = true;
        self.shared.close();
        for token in self.shared.inflight.lock().unwrap().values() {
            token.cancel();
        }
        if let Some(token) = self.legacy_active.take() {
            token.cancel();
        }
        self.legacy_queue.clear();
        ctl.metrics().on_conn_closed();
        log_debug!("conn {} ({}) closed: {why}", self.shared.id, self.peer);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{FinishReason, Response, RoundStats};
    use crate::server::reactor::Poller;

    fn mk_shared(cap: usize) -> Arc<ConnShared> {
        let poller = Poller::new().unwrap();
        ConnShared::new(
            1,
            cap,
            ReactorHandle::new(poller.waker()),
            Arc::new(Metrics::new()),
        )
    }

    /// Pop one queued frame back as its wire line (newline stripped).
    fn pop_line(shared: &ConnShared) -> Option<String> {
        shared.pop_frame().map(|bytes| {
            let mut s = String::from_utf8(bytes).expect("frames are utf-8");
            assert_eq!(s.pop(), Some('\n'), "frame not newline-terminated");
            s
        })
    }

    fn resp(finish: FinishReason) -> Box<Response> {
        Box::new(Response {
            id: 1,
            worker: 0,
            tokens: vec![4, 5],
            steps: 1,
            emitted_per_step: 2.0,
            queue_secs: 0.0,
            gen_secs: 0.0,
            ttft_secs: 0.0,
            virtual_secs: 0.0,
            cache_hits: 0,
            finish,
        })
    }

    /// The backpressure mechanism, isolated from kernel socket buffers:
    /// pushes beyond the cap are refused and flag the connection for
    /// teardown; the gauge tracks queued frames exactly.
    #[test]
    fn outbox_cap_refuses_and_flags_overflow() {
        let shared = mk_shared(2);
        assert!(shared.push_frame("a".into()));
        assert!(shared.push_frame("b".into()));
        assert!(!shared.push_frame("c".into()));
        assert!(shared.overflowed.load(Ordering::SeqCst));
        assert_eq!(shared.metrics.outbox_frames(), 2);
        assert_eq!(pop_line(&shared).as_deref(), Some("a"));
        assert_eq!(shared.metrics.outbox_frames(), 1);
        shared.close();
        assert_eq!(shared.metrics.outbox_frames(), 0);
        assert!(!shared.push_frame("d".into()), "closed outbox accepted");
    }

    /// The sink serializes chunk + done into wire frames in the outbox,
    /// and frees the req_id BEFORE queueing the terminal frame.
    #[test]
    fn sink_frames_events_and_frees_req_id_first() {
        let shared = mk_shared(16);
        shared
            .inflight
            .lock()
            .unwrap()
            .insert(7, CancelToken::new());
        let sink = ConnSink::new(
            7,
            true,
            false,
            shared.clone(),
            Arc::new(AtomicBool::new(true)),
        );
        use crate::coordinator::EventSink;
        assert!(sink.send(GenEvent::Chunk {
            tokens: vec![9, 8],
            stats: RoundStats::default(),
        }));
        assert!(sink.send(GenEvent::Done(resp(FinishReason::Length))));
        assert!(!shared.inflight.lock().unwrap().contains_key(&7));

        let chunk =
            protocol::parse_frame(&pop_line(&shared).unwrap()).unwrap();
        assert_eq!((chunk.req_id, chunk.event.as_str()), (Some(7), "chunk"));
        assert_eq!(chunk.tokens(), vec![9, 8]);
        let done = protocol::parse_frame(&pop_line(&shared).unwrap()).unwrap();
        assert_eq!((done.req_id, done.event.as_str()), (Some(7), "done"));
        assert!(done.tokens().is_empty(), "streamed done repeats tokens");
        drop(sink); // done was sent: drop emits nothing further
        assert!(shared.pop_frame().is_none());
    }

    /// One-shot (stream=false) sinks suppress chunk frames; legacy sinks
    /// reply with the bare v0 object and flip the FIFO-advance flag.
    #[test]
    fn oneshot_and_legacy_sink_shapes() {
        let shared = mk_shared(16);
        use crate::coordinator::EventSink;
        let oneshot = ConnSink::new(
            3,
            false,
            false,
            shared.clone(),
            Arc::new(AtomicBool::new(true)),
        );
        assert!(oneshot.send(GenEvent::Chunk {
            tokens: vec![1],
            stats: RoundStats::default(),
        }));
        assert!(shared.pop_frame().is_none(), "one-shot leaked a chunk");
        assert!(oneshot.send(GenEvent::Done(resp(FinishReason::Length))));
        let done = protocol::parse_frame(&pop_line(&shared).unwrap()).unwrap();
        assert_eq!(done.tokens(), vec![4, 5], "one-shot done carries tokens");

        let legacy = ConnSink::new(
            0,
            false,
            true,
            shared.clone(),
            Arc::new(AtomicBool::new(true)),
        );
        assert!(legacy.send(GenEvent::Done(resp(FinishReason::Length))));
        assert!(shared.legacy_finished.load(Ordering::SeqCst));
        let reply = pop_line(&shared).unwrap();
        let doc = parse_json(&reply).unwrap();
        assert!(doc.get("event").is_none(), "legacy reply got enveloped");
        assert_eq!(doc.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }

    /// The zero-copy write path: queued frames are stored as
    /// newline-terminated bytes and one load coalesces the whole burst
    /// into a single write buffer (one syscall), draining the gauge.
    #[test]
    fn load_partial_coalesces_queued_frames() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let shared = mk_shared(16);
        let mut conn = Conn::new(stream, shared.clone());
        assert!(shared.push_frame("a".into()));
        assert!(shared.push_frame("bb".into()));
        assert!(shared.push_frame("ccc".into()));
        assert!(conn.load_partial());
        assert_eq!(conn.partial.as_slice(), b"a\nbb\nccc\n".as_slice());
        assert_eq!(shared.outbox_len(), 0, "burst not fully coalesced");
        assert_eq!(shared.metrics.outbox_frames(), 0, "gauge not drained");
        // The pending buffer stays loaded until fully written.
        assert!(conn.load_partial());
        assert_eq!(conn.written, 0);
        drop(client);
    }

    /// With a trace attached (admission minted one), every v1 frame of
    /// the stream — chunk, done, and the drop-path error — echoes it as
    /// 16 lowercase hex; without one, no `trace` key appears at all
    /// (wire bit-identity when tracing is off).
    #[test]
    fn sink_echoes_attached_trace_on_every_v1_frame() {
        use crate::coordinator::EventSink;
        let shared = mk_shared(16);
        let traced = ConnSink::new(
            7,
            true,
            false,
            shared.clone(),
            Arc::new(AtomicBool::new(true)),
        );
        traced.attach_trace(0xabc1_2345_6789_0def);
        assert!(traced.send(GenEvent::Chunk {
            tokens: vec![1],
            stats: RoundStats::default(),
        }));
        let chunk =
            protocol::parse_frame(&pop_line(&shared).unwrap()).unwrap();
        assert_eq!(chunk.trace(), Some("abc1234567890def"));
        assert!(traced.send(GenEvent::Done(resp(FinishReason::Length))));
        let done = protocol::parse_frame(&pop_line(&shared).unwrap()).unwrap();
        assert_eq!(done.trace(), Some("abc1234567890def"));

        // Drop-path terminal error carries it too.
        let dropped = ConnSink::new(
            8,
            true,
            false,
            shared.clone(),
            Arc::new(AtomicBool::new(true)),
        );
        dropped.attach_trace(0x1);
        drop(dropped);
        let err = protocol::parse_frame(&pop_line(&shared).unwrap()).unwrap();
        assert_eq!(err.event.as_str(), "error");
        assert_eq!(err.trace(), Some("0000000000000001"));

        // No trace attached: the key is absent, not empty.
        let untraced = ConnSink::new(
            9,
            true,
            false,
            shared.clone(),
            Arc::new(AtomicBool::new(true)),
        );
        assert!(untraced.send(GenEvent::Done(resp(FinishReason::Length))));
        let plain = pop_line(&shared).unwrap();
        assert!(!plain.contains("trace"), "untraced frame grew a key: {plain}");
    }

    /// An admitted sink dropped without its Done (coordinator teardown)
    /// emits the terminal error frame; an unadmitted one (rejected
    /// submission) stays silent — the submitter already answered.
    #[test]
    fn sink_drop_semantics() {
        let shared = mk_shared(16);
        let admitted = ConnSink::new(
            5,
            true,
            false,
            shared.clone(),
            Arc::new(AtomicBool::new(true)),
        );
        drop(admitted);
        let frame =
            protocol::parse_frame(&pop_line(&shared).unwrap()).unwrap();
        assert_eq!((frame.req_id, frame.event.as_str()), (Some(5), "error"));
        assert_eq!(frame.error(), Some("worker dropped request"));

        let unadmitted = ConnSink::new(
            6,
            true,
            false,
            shared.clone(),
            Arc::new(AtomicBool::new(false)),
        );
        drop(unadmitted);
        assert!(shared.pop_frame().is_none(), "unadmitted drop spoke");
    }
}
