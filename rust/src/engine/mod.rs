//! The FCFS speculative decoding front end: one request at a time, one
//! speculation round per step, each round a **batch-of-1 instance of the
//! shared round pipeline** (`crate::round`, DESIGN.md §Round Pipeline) —
//! draft tree → DFS reorder → parallel target verification → accept a
//! root path + bonus token. The engine owns what is per-request about the
//! FCFS path (the running sampling stream, the per-generation cache
//! session, chunk truncation and emission) and collects the per-step
//! statistics every paper table/figure is computed from, including the
//! virtual hardware-regime latency ledger (DESIGN.md §3) the pipeline
//! prices.

pub mod events;
pub mod stats;

pub use events::{
    truncate_chunk, CancelToken, EventSink, FinishReason, GenEvent, GenParams,
    Response, RoundStats,
};
pub use stats::{GenerationStats, StepStats};

use std::sync::Arc;

use crate::cache::CacheManager;
use crate::config::{
    AdaptConfig, CacheConfig, EngineConfig, LatencyRegime, PolicyKind,
};
use crate::draft::{make_policy, TreePolicy};
use crate::models::LogitModel;
use crate::obs::{Observatory, TraceId};
use crate::round::adapt::AdaptiveController;
use crate::round::{self, RoundCtx, SeqRound};
use crate::util::Rng;

/// The engine serves one generation at a time; its cache manager tracks
/// that single sequence under a fixed id.
const ENGINE_SEQ: u64 = 0;

/// Speculative decoding engine over a (draft, target) model pair.
pub struct SpecEngine {
    pub draft: Box<dyn LogitModel>,
    pub target: Box<dyn LogitModel>,
    pub policy: Box<dyn TreePolicy>,
    pub cfg: EngineConfig,
    pub regime: Option<LatencyRegime>,
    rng: Rng,
    /// KV prefix residency across this generation's speculation rounds
    /// (reset at every `generate`; default-enabled, see `CacheConfig`).
    cache: CacheManager,
    /// Observatory + worker id for per-round span/acceptance recording
    /// (`None` for standalone engines — benches, tests).
    obs: Option<(Arc<Observatory>, usize)>,
    /// Current request's trace id (0 = untraced).
    trace: u64,
    /// The engine-level default drafter ([`Self::set_policy`]); the
    /// static-mode round resolution falls back here, not to the
    /// possibly-drifted `cfg.policy` (which [`Self::ensure_policy`]
    /// syncs to whatever drafter the *current* round runs).
    base_policy: PolicyKind,
    /// Per-request drafter override (protocol-v1 `drafter` param); wins
    /// over both the adaptive controller and the base policy.
    request_drafter: Option<PolicyKind>,
    /// Online drafter/budget selection (`policy_mode=adaptive`,
    /// DESIGN.md §Adaptive Policy); `None` keeps the static path.
    adapt: Option<AdaptiveController>,
}

impl SpecEngine {
    pub fn new(
        draft: Box<dyn LogitModel>,
        target: Box<dyn LogitModel>,
        cfg: EngineConfig,
        regime: Option<LatencyRegime>,
    ) -> Self {
        let rng = Rng::new(cfg.seed ^ 0x0DD5_9EC0_0000_0001);
        let policy = make_policy(cfg.policy);
        let base_policy = cfg.policy;
        Self {
            draft,
            target,
            policy,
            cfg,
            regime,
            rng,
            cache: CacheManager::new(&CacheConfig::default()),
            obs: None,
            trace: 0,
            base_policy,
            request_drafter: None,
            adapt: None,
        }
    }

    /// Replace the KV-cache configuration (builder style; `enabled: false`
    /// restores the re-score-from-zero behaviour).
    pub fn with_cache(mut self, cache: &CacheConfig) -> Self {
        self.cache = CacheManager::new(cache);
        self
    }

    /// Attach the worker's observatory (builder style): each round then
    /// lands its stage latencies and acceptance counters there, plus a
    /// span per stage when tracing is enabled. Recording reads only data
    /// the round already computed — the sampling stream is untouched.
    pub fn with_obs(mut self, obs: Arc<Observatory>, wid: usize) -> Self {
        self.obs = Some((obs, wid));
        self
    }

    /// Set the trace id rounds are tagged with (0 = untraced; called per
    /// request by the FCFS worker).
    pub fn set_trace(&mut self, trace: u64) {
        self.trace = trace;
    }

    pub fn cache(&self) -> &CacheManager {
        &self.cache
    }

    /// Re-seed the engine's sampling stream (per-request determinism: a
    /// protocol-v1 request carrying `seed` gets the same stream no matter
    /// which worker picks it up or what ran before it).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Rng::new(seed ^ 0x0DD5_9EC0_0000_0001);
    }

    /// Enable online-adaptive drafter/budget selection (builder style).
    /// A `policy_mode=static` config is a no-op, so callers can pass
    /// their `cfg.adapt` unconditionally.
    pub fn with_adapt(mut self, adapt: &AdaptConfig) -> Self {
        self.adapt = AdaptiveController::new(adapt, self.base_policy);
        self
    }

    /// Swap the engine's default draft-tree policy.
    pub fn set_policy(&mut self, kind: PolicyKind) {
        self.base_policy = kind;
        self.ensure_policy(kind);
    }

    /// Set (or clear) the per-request drafter override; `Some` pins the
    /// round's drafter regardless of mode, `None` restores the
    /// adaptive/static resolution. Called per request by the FCFS worker.
    pub fn set_request_drafter(&mut self, drafter: Option<PolicyKind>) {
        self.request_drafter = drafter;
    }

    /// Make the boxed policy (and `cfg.policy`, which the round pipeline
    /// and observatory read) match `kind`, rebuilding only on change.
    fn ensure_policy(&mut self, kind: PolicyKind) {
        if self.cfg.policy != kind {
            self.cfg.policy = kind;
            self.policy = make_policy(kind);
        }
    }

    /// Generate up to `cfg.max_new_tokens` tokens after `prompt`.
    pub fn generate(&mut self, prompt: &[u32]) -> GenerationStats {
        self.generate_streamed(prompt, None, |_| {}).0
    }

    /// Incremental generation: every speculation round pushes its accepted
    /// chunk through `sink` as a [`GenEvent::Chunk`] (the engine never
    /// emits `Done` — the serving layer does, with the aggregate
    /// [`Response`]). Between rounds the optional `cancel` token is
    /// checked; a cancelled generation returns the tokens emitted so far
    /// with [`FinishReason::Cancelled`]. A token in `cfg.stop_tokens`
    /// truncates the chunk after (and including) it and finishes with
    /// [`FinishReason::Stop`].
    pub fn generate_streamed<F: FnMut(GenEvent)>(
        &mut self,
        prompt: &[u32],
        cancel: Option<&CancelToken>,
        mut sink: F,
    ) -> (GenerationStats, FinishReason) {
        assert!(!prompt.is_empty(), "empty prompt");
        // Fresh cache session per generation: the previous request's
        // PRIVATE residency is released here. With `cache.radix=on` its
        // published prefix stays resident in the shared radix tree, so
        // this request's first `begin_round` may start warm at the
        // longest shared prefix — warm positions bill as cached fetches
        // and the token stream is untouched.
        self.cache.drop_seq(ENGINE_SEQ);
        let mut ctx = prompt.to_vec();
        let mut stats = GenerationStats::new(prompt.len());
        let mut finish = FinishReason::Length;

        // Chunked prefill (DESIGN.md §Chunked Prefill): compute the cold
        // prompt in block-aligned chunks of at most `prefill_chunk`
        // tokens, one bare prefill round each, so the eventual first
        // speculation round pays at most `prefill_chunk` fresh prompt
        // positions plus its tree rows. Chunks emit nothing and draw
        // nothing from the rng, so the token stream is bit-identical to
        // the one-shot path (`prefill_chunk=0`, the default) — pinned by
        // `rust/tests/prefill_equivalence.rs`. The loop always leaves at
        // least one prompt position for the first speculation round.
        let chunk = self.cfg.prefill_chunk;
        if chunk > 0 {
            let b = self.cache.block_tokens().max(1);
            let mut pos = 0usize;
            while ctx.len() - pos > chunk {
                if cancel.map(CancelToken::is_cancelled).unwrap_or(false) {
                    break; // the main loop settles finish=Cancelled
                }
                // Chunk ends round down to a block boundary so committed
                // residency (and radix publication) is block-tight; tiny
                // chunks still make >= 1 token of progress.
                let mut end = ((pos + chunk) / b) * b;
                if end <= pos {
                    end = pos + chunk;
                }
                let step = self.prefill_step(&ctx[..end]);
                // No sink call: prefill chunks are not emissions, so TTFT
                // stays pinned to the first real chunk.
                stats.push_step(
                    Vec::new(),
                    step,
                    &mut ctx,
                    self.cfg.max_new_tokens,
                );
                pos = end;
            }
        }

        while stats.tokens.len() < self.cfg.max_new_tokens {
            if cancel.map(CancelToken::is_cancelled).unwrap_or(false) {
                finish = FinishReason::Cancelled;
                break;
            }
            let remaining = self.cfg.max_new_tokens - stats.tokens.len();
            let (mut tokens, mut step) = self.round_step(&ctx, remaining);
            let stopped =
                truncate_chunk(&mut tokens, &self.cfg.stop_tokens, remaining);
            step.emitted = tokens.len();
            let before = stats.tokens.len();
            stats.push_step(tokens, step, &mut ctx, remaining);
            let chunk = stats.tokens[before..].to_vec();
            if stopped {
                finish = FinishReason::Stop;
            }
            let last = stats.steps.last().expect("step just pushed");
            sink(GenEvent::Chunk {
                stats: RoundStats {
                    round: stats.steps.len(),
                    tree_size: last.tree_size,
                    accepted: last.accepted_speculated,
                    billed_positions: last.billed_positions,
                    cached_positions: last.cached_positions,
                    virtual_secs: last.virtual_secs.unwrap_or(0.0),
                },
                tokens: chunk,
            });
            if stopped {
                break;
            }
        }
        // The request is complete (or cancelled): release its private
        // residency now rather than holding the blocks while the worker
        // sits idle (radix off, the resident-block gauge returns to zero
        // between requests; radix on, published shared blocks stay
        // resident — unpinned — for the next request to warm-start on).
        self.cache.drop_seq(ENGINE_SEQ);
        (stats, finish)
    }

    /// One speculation round = a batch-of-1 instance of the shared round
    /// pipeline (`crate::round`). The pipeline owns draft-tree growth,
    /// mask construction, the incremental verification dispatch,
    /// acceptance + bonus sampling, cache lease commit/rollback, and the
    /// cost accounting; this method only adapts its outcome into the
    /// engine's per-step statistics. `PolicyKind::Baseline` takes the
    /// pipeline's bare-verification-row path — plain autoregressive
    /// decoding with no draft cost — and so does the final round when
    /// exactly one token remains (the continuous batcher's Drain rule:
    /// the bonus token needs no speculated tree, so FCFS and
    /// continuous-with-one-slot run identical rounds end to end; pinned
    /// by `rust/tests/round_equivalence.rs`).
    fn round_step(
        &mut self,
        ctx: &[u32],
        remaining: usize,
    ) -> (Vec<u32>, StepStats) {
        // Round resolution: a per-request override pins the drafter at
        // the base budget; otherwise the adaptive controller (when
        // enabled) picks drafter + budget; otherwise the static default.
        let base_budget = self.cfg.tree_budget;
        let (kind, budget) = match (self.request_drafter, &self.adapt) {
            (Some(k), _) => (k, base_budget),
            (None, Some(a)) => a.resolve(base_budget),
            (None, None) => (self.base_policy, base_budget),
        };
        self.ensure_policy(kind);
        let rc = RoundCtx {
            cfg: &self.cfg,
            policy: self.policy.as_ref(),
            policy_kind: kind,
            global_budget: budget,
            regime: self.regime,
        };
        let mut seqs = [SeqRound {
            id: ENGINE_SEQ,
            prefix: ctx,
            rng: &mut self.rng,
            temperature: self.cfg.target_temp,
            cap: budget,
            wants_spec: remaining > 1,
            prefill: false,
        }];
        let outcome = round::run_round(
            &rc,
            self.draft.as_mut(),
            self.target.as_mut(),
            &mut self.cache,
            &mut seqs,
        );
        if let Some(a) = &mut self.adapt {
            a.observe(kind, &outcome.accept);
        }
        if let Some((obs, wid)) = &self.obs {
            obs.record_round(
                *wid,
                TraceId(self.trace),
                1,
                self.cfg.policy,
                &outcome.times,
                &outcome.accept,
            );
        }
        let seq = outcome.seqs.into_iter().next().expect("batch of one");
        let step = StepStats {
            tree_size: seq.allocated,
            tree_depth: seq.tree_depth,
            accepted_speculated: seq.accepted,
            emitted: seq.tokens.len(),
            draft_dispatches: outcome.draft_dispatches,
            target_dispatches: outcome.target_dispatches,
            billed_positions: seq.bill.billed_positions,
            cached_positions: seq.bill.cached_positions,
            warm_start_tokens: seq.warm_start,
            prefill: false,
            prefill_tokens: 0,
            times: outcome.times,
            virtual_secs: outcome.virtual_secs,
        };
        (seq.tokens, step)
    }

    /// One prefill chunk round: a batch-of-1 prefill row over a partial
    /// prompt (`round::SeqRound::prefill`). Commits the chunk's positions
    /// into residency — and, radix on, publishes them — while sampling
    /// nothing; the rng stream is untouched. No draft tree is built, so
    /// drafter resolution (adaptive or static) is irrelevant here.
    fn prefill_step(&mut self, ctx: &[u32]) -> StepStats {
        let rc = RoundCtx {
            cfg: &self.cfg,
            policy: self.policy.as_ref(),
            policy_kind: self.cfg.policy,
            global_budget: 0,
            regime: self.regime,
        };
        let mut seqs = [SeqRound {
            id: ENGINE_SEQ,
            prefix: ctx,
            rng: &mut self.rng,
            temperature: self.cfg.target_temp,
            cap: 0,
            wants_spec: false,
            prefill: true,
        }];
        let outcome = round::run_round(
            &rc,
            self.draft.as_mut(),
            self.target.as_mut(),
            &mut self.cache,
            &mut seqs,
        );
        if let Some((obs, wid)) = &self.obs {
            obs.record_round(
                *wid,
                TraceId(self.trace),
                1,
                self.cfg.policy,
                &outcome.times,
                &outcome.accept,
            );
        }
        let seq = outcome.seqs.into_iter().next().expect("batch of one");
        StepStats {
            tree_size: 0,
            tree_depth: 0,
            accepted_speculated: 0,
            emitted: 0,
            draft_dispatches: 0,
            target_dispatches: outcome.target_dispatches,
            billed_positions: seq.bill.billed_positions,
            cached_positions: seq.bill.cached_positions,
            warm_start_tokens: seq.warm_start,
            prefill: true,
            prefill_tokens: outcome.prefill_tokens,
            times: outcome.times,
            virtual_secs: outcome.virtual_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::sim::{SimModel, SimSpec};

    fn engine(policy: PolicyKind, noise: f32, temp: f32, seed: u64) -> SpecEngine {
        let spec = SimSpec::new(64, 2.0, noise, 7);
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy,
            tree_budget: 16,
            max_new_tokens: 40,
            target_temp: temp,
            seed,
            ..EngineConfig::default()
        };
        SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
    }

    #[test]
    fn generates_exact_token_count() {
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 1);
        let out = e.generate(&[1, 2, 3]);
        assert_eq!(out.tokens.len(), 40);
        assert!(out.steps.len() <= 40);
        assert!(out.mean_emitted_per_step() >= 1.0);
    }

    #[test]
    fn baseline_emits_one_per_step() {
        let mut e = engine(PolicyKind::Baseline, 0.8, 0.6, 2);
        let out = e.generate(&[5, 6]);
        assert_eq!(out.tokens.len(), 40);
        assert_eq!(out.steps.len(), 40);
        assert!((out.mean_emitted_per_step() - 1.0).abs() < 1e-9);
    }

    /// The paper's core claim at engine level: with a decent draft model,
    /// DySpec accepts more tokens/step than a chain, which beats baseline.
    /// Averaged over several prompts/seeds (single runs are noisy at this
    /// scale; the full-population comparison is the table1 bench).
    #[test]
    fn dyspec_beats_chain_beats_baseline_on_acceptance() {
        let run = |policy| {
            let mut tokens = 0usize;
            let mut steps = 0usize;
            for seed in 0..6u64 {
                let spec = SimSpec::new(64, 2.0, 1.0, 7);
                let (draft, target) = SimModel::pair(spec);
                let cfg = EngineConfig {
                    policy,
                    tree_budget: 24,
                    max_new_tokens: 48,
                    target_temp: 0.6,
                    seed,
                    ..EngineConfig::default()
                };
                let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
                let out = e.generate(&[9 + seed as u32, 8, 7, 6]);
                tokens += out.tokens.len();
                steps += out.steps.len();
            }
            tokens as f64 / steps as f64
        };
        let dyspec = run(PolicyKind::DySpec);
        let chain = run(PolicyKind::Chain);
        let baseline = run(PolicyKind::Baseline);
        assert!(dyspec > chain, "dyspec {dyspec} <= chain {chain}");
        assert!(chain > baseline, "chain {chain} <= baseline {baseline}");
    }

    /// temp=0 + zero-noise draft == deterministic greedy decoding: the
    /// speculative engine must produce EXACTLY the autoregressive sequence.
    #[test]
    fn temp0_perfect_draft_matches_autoregressive() {
        let spec = SimSpec::new(32, 2.0, 0.0, 11);
        let mk = |policy| {
            let (draft, target) = SimModel::pair(spec);
            let cfg = EngineConfig {
                policy,
                tree_budget: 8,
                max_new_tokens: 24,
                target_temp: 0.0,
                draft_temp: 0.0,
                seed: 4,
                ..EngineConfig::default()
            };
            SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
        };
        let spec_tokens = mk(PolicyKind::DySpec).generate(&[1, 2]).tokens;
        let ar_tokens = mk(PolicyKind::Baseline).generate(&[1, 2]).tokens;
        assert_eq!(spec_tokens, ar_tokens);
    }

    #[test]
    fn virtual_latency_accounts_regime() {
        let spec = SimSpec::new(64, 2.0, 0.5, 7);
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            tree_budget: 16,
            max_new_tokens: 12,
            seed: 5,
            ..EngineConfig::default()
        };
        let regime = LatencyRegime::pair_7b();
        let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, Some(regime));
        let out = e.generate(&[3, 4, 5]);
        let v = out.total_virtual_secs();
        // at least one target step per engine step
        assert!(v >= regime.target_step_secs * out.steps.len() as f64);
        // and draft costs are in there too
        let draft_total: u64 = out.steps.iter().map(|s| s.draft_dispatches).sum();
        assert!(v >= regime.target_step_secs * out.steps.len() as f64
            + regime.draft_step_secs * draft_total as f64 * 0.99);
    }

    /// The tentpole property at engine level: with residency, every round
    /// past the first bills only the fresh positions (bonus token + tree),
    /// never the whole context — and outputs are unchanged.
    #[test]
    fn cache_residency_shrinks_billed_positions() {
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 9);
        let out = e.generate(&prompt);
        assert!(out.steps.len() >= 2, "need multiple rounds");
        let first = &out.steps[0];
        assert_eq!(first.cached_positions, 0);
        assert_eq!(
            first.billed_positions,
            prompt.len() + first.tree_size,
            "cold round must bill the full prefix + tree"
        );
        for s in &out.steps[1..] {
            assert!(s.cached_positions > 0, "no residency after round 1");
        }

        let mut uncached = engine(PolicyKind::DySpec, 0.8, 0.6, 9).with_cache(
            &crate::config::CacheConfig {
                enabled: false,
                ..crate::config::CacheConfig::default()
            },
        );
        let out2 = uncached.generate(&prompt);
        assert_eq!(out.tokens, out2.tokens, "cache changed the output");
        assert_eq!(out.steps.len(), out2.steps.len());
        for (warm, cold) in out.steps.iter().zip(&out2.steps).skip(1) {
            assert!(
                warm.billed_positions < cold.billed_positions,
                "warm round billed {} >= cold {}",
                warm.billed_positions,
                cold.billed_positions
            );
        }
    }

    /// The streaming tentpole at engine level: concatenated chunk events
    /// are bit-identical to the one-shot token array for the same seed,
    /// and the final round stats agree with the aggregate.
    #[test]
    fn streamed_chunks_concatenate_to_one_shot_tokens() {
        let oneshot = engine(PolicyKind::DySpec, 0.8, 0.6, 12)
            .generate(&[4, 5, 6])
            .tokens;
        let mut chunks: Vec<u32> = Vec::new();
        let mut rounds = 0usize;
        let (stats, finish) = engine(PolicyKind::DySpec, 0.8, 0.6, 12)
            .generate_streamed(&[4, 5, 6], None, |ev| {
                if let GenEvent::Chunk { tokens, stats } = ev {
                    rounds += 1;
                    assert_eq!(stats.round, rounds);
                    assert!(!tokens.is_empty(), "empty chunk");
                    chunks.extend_from_slice(&tokens);
                }
            });
        assert_eq!(chunks, oneshot, "streamed chunks diverged from one-shot");
        assert_eq!(chunks, stats.tokens);
        assert_eq!(rounds, stats.steps.len());
        assert_eq!(finish, FinishReason::Length);
    }

    /// Chunked prefill at engine level: the token stream is bit-identical
    /// to one-shot, the extra steps are exactly the chunk rounds (which
    /// emit nothing and build no trees), and with the cache on the total
    /// computed positions match — chunking only re-times the prompt work,
    /// it never re-does it. (The full matrix across schedulers × cache ×
    /// radix × drafters lives in `rust/tests/prefill_equivalence.rs`.)
    #[test]
    fn chunked_prefill_matches_one_shot_and_rebills_nothing() {
        let prompt: Vec<u32> = (1..=37).collect();
        let cache = crate::config::CacheConfig {
            block_tokens: 4,
            ..crate::config::CacheConfig::default()
        };
        let mut off = engine(PolicyKind::DySpec, 0.8, 0.6, 23).with_cache(&cache);
        let base = off.generate(&prompt);

        let mut on = engine(PolicyKind::DySpec, 0.8, 0.6, 23).with_cache(&cache);
        on.cfg.prefill_chunk = 8;
        let chunked = on.generate(&prompt);

        assert_eq!(chunked.tokens, base.tokens, "chunking changed the stream");
        // 37-token prompt, chunk 8, block 4: chunks end at 8/16/24/32, the
        // final 5 prompt positions ride the first speculation round.
        assert_eq!(chunked.total_prefill_chunks(), 4);
        assert_eq!(chunked.total_prefill_tokens(), 32);
        assert_eq!(chunked.steps.len(), base.steps.len() + 4);
        for s in &chunked.steps[..4] {
            assert!(s.prefill);
            assert_eq!(s.emitted, 0);
            assert_eq!(s.tree_size, 0);
            assert_eq!(s.draft_dispatches, 0);
        }
        assert!(chunked.steps[4..].iter().all(|s| !s.prefill));
        assert_eq!(
            chunked.total_billed_positions(),
            base.total_billed_positions(),
            "chunking re-billed prompt positions"
        );
        assert_eq!(chunked.steps[4].cached_positions, 32, "chunks not resident");
    }

    #[test]
    fn cancel_between_rounds_returns_partial_output() {
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 3);
        let cancel = CancelToken::new();
        let handle = cancel.clone();
        let mut seen = 0usize;
        let (stats, finish) =
            e.generate_streamed(&[1, 2, 3], Some(&cancel), |_| {
                seen += 1;
                if seen == 2 {
                    handle.cancel();
                }
            });
        assert_eq!(finish, FinishReason::Cancelled);
        assert_eq!(stats.steps.len(), 2, "cancel not honored next round");
        assert!(stats.tokens.len() < 40);
        // Residency released on the cancel path too.
        assert_eq!(e.cache().used_blocks(), 0);
    }

    #[test]
    fn pre_cancelled_generation_emits_nothing() {
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 3);
        let cancel = CancelToken::new();
        cancel.cancel();
        let (stats, finish) =
            e.generate_streamed(&[1, 2, 3], Some(&cancel), |_| {});
        assert_eq!(finish, FinishReason::Cancelled);
        assert!(stats.tokens.is_empty());
        assert!(stats.steps.is_empty());
    }

    #[test]
    fn stop_token_truncates_chunk_and_finishes() {
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 1);
        // Find out what the stream emits, then re-run stopping at the
        // third token.
        let tokens = e.generate(&[7, 8]).tokens;
        let stop = tokens[2];
        let first_hit = tokens.iter().position(|&t| t == stop).unwrap();
        let mut e2 = engine(PolicyKind::DySpec, 0.8, 0.6, 1);
        e2.cfg.stop_tokens = vec![stop];
        let (stats, finish) = e2.generate_streamed(&[7, 8], None, |_| {});
        assert_eq!(finish, FinishReason::Stop);
        assert_eq!(stats.tokens.last(), Some(&stop));
        assert_eq!(stats.tokens.len(), first_hit + 1);
        assert_eq!(&stats.tokens[..], &tokens[..first_hit + 1]);
    }

    #[test]
    fn reseed_makes_requests_deterministic_on_a_warm_engine() {
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 5);
        e.reseed(77);
        let a = e.generate(&[3, 1, 4]).tokens;
        // Engine rng has advanced; an unseeded rerun would diverge.
        e.reseed(77);
        let b = e.generate(&[3, 1, 4]).tokens;
        assert_eq!(a, b, "reseed did not pin the sampling stream");
    }

    #[test]
    fn set_policy_switches_step_kind() {
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 2);
        e.set_policy(PolicyKind::Baseline);
        let out = e.generate(&[5, 6]);
        assert_eq!(out.steps.len(), out.tokens.len(), "not autoregressive");
        e.set_policy(PolicyKind::DySpec);
        let out = e.generate(&[5, 6]);
        assert!(out.mean_emitted_per_step() >= 1.0);
    }

    /// The tentpole equivalence at engine level: adaptive mode with one
    /// registered drafter never consults the estimator, so the token
    /// stream is bit-identical to static mode. (The full matrix across
    /// schedulers × cache lives in `rust/tests/adaptive_differential.rs`.)
    #[test]
    fn adaptive_singleton_matches_static_bit_for_bit() {
        let static_tokens =
            engine(PolicyKind::DySpec, 0.8, 0.6, 13).generate(&[2, 7]).tokens;
        let adapt_cfg = AdaptConfig {
            mode: crate::config::PolicyMode::Adaptive,
            drafters: vec![PolicyKind::DySpec],
            ..AdaptConfig::default()
        };
        let mut e =
            engine(PolicyKind::DySpec, 0.8, 0.6, 13).with_adapt(&adapt_cfg);
        let adaptive_tokens = e.generate(&[2, 7]).tokens;
        assert_eq!(adaptive_tokens, static_tokens);
    }

    /// With ≥2 registered drafters the controller explores each cold arm
    /// and records observations against the drafter that actually ran.
    #[test]
    fn adaptive_multi_drafter_explores_and_observes() {
        let adapt_cfg = AdaptConfig {
            mode: crate::config::PolicyMode::Adaptive,
            drafters: vec![PolicyKind::DySpec, PolicyKind::Chain],
            min_samples: 8,
            ..AdaptConfig::default()
        };
        let obs = Arc::new(Observatory::new(1, false, 8));
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 17)
            .with_adapt(&adapt_cfg)
            .with_obs(obs.clone(), 0);
        let out = e.generate(&[1, 2, 3, 4]);
        assert_eq!(out.tokens.len(), 40);
        let table = obs.acceptance();
        assert_eq!(table.len(), 2, "a cold drafter was never explored");
        assert!(table.iter().all(|(_, rec)| rec.proposed() > 0));
    }

    /// A per-request drafter override wins over the adaptive controller.
    #[test]
    fn request_drafter_override_pins_the_round_kind() {
        let adapt_cfg = AdaptConfig {
            mode: crate::config::PolicyMode::Adaptive,
            drafters: vec![PolicyKind::DySpec, PolicyKind::Chain],
            ..AdaptConfig::default()
        };
        let mut e =
            engine(PolicyKind::DySpec, 0.8, 0.6, 19).with_adapt(&adapt_cfg);
        e.set_request_drafter(Some(PolicyKind::Baseline));
        let out = e.generate(&[5, 6]);
        assert_eq!(out.steps.len(), out.tokens.len(), "not autoregressive");
        e.set_request_drafter(None);
        let out = e.generate(&[5, 6]);
        assert!(out.mean_emitted_per_step() > 1.0, "override stuck");
    }

    #[test]
    fn stats_component_times_cover_pipeline() {
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 6);
        let out = e.generate(&[1, 2, 3]);
        let agg = out.aggregate_times();
        for key in ["draft_infer", "tree_construct", "mask", "target_infer", "verify", "sample", "commit"] {
            assert!(agg.get(key) >= 0.0);
        }
        assert!(agg.total() > 0.0);
    }

    /// An engine wired to an observatory lands stage latencies and
    /// acceptance counters for every round, and spans only when tracing —
    /// with token output identical either way.
    #[test]
    fn attached_observatory_records_rounds_without_changing_tokens() {
        let bare = engine(PolicyKind::DySpec, 0.8, 0.6, 21).generate(&[2, 3]).tokens;

        let obs = Arc::new(Observatory::new(1, true, 64));
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 21).with_obs(obs.clone(), 0);
        e.set_trace(TraceId::mint(42).0);
        let traced = e.generate(&[2, 3]).tokens;
        assert_eq!(traced, bare, "observatory perturbed the token stream");

        let q = obs.stage_quantiles();
        assert_eq!(q.len(), 5);
        assert!(q.iter().all(|(_, n, ..)| *n > 0), "stage histogram empty");
        let (spans, _) = obs.dump_spans();
        assert!(!spans.is_empty());
        assert!(spans.iter().all(|s| s.trace == TraceId::mint(42).0));
        let table = obs.acceptance();
        assert_eq!(table.len(), 1);
        assert_eq!(table[0].0, "dyspec");
        assert!(table[0].1.proposed() > 0);

        let quiet = Arc::new(Observatory::new(1, false, 64));
        let mut e = engine(PolicyKind::DySpec, 0.8, 0.6, 21).with_obs(quiet.clone(), 0);
        let untraced = e.generate(&[2, 3]).tokens;
        assert_eq!(untraced, bare);
        assert!(quiet.dump_spans().0.is_empty(), "spans recorded while off");
        assert!(!quiet.acceptance().is_empty(), "counters must stay on");
    }
}
