//! Per-step and per-generation statistics — the raw material for every
//! table and figure reproduction (accepted tokens/step, latency/token,
//! component breakdowns, tree sizes over time).

use crate::util::timer::ComponentTimes;

/// Statistics for one engine step.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub tree_size: usize,
    pub tree_depth: usize,
    /// Speculated tokens accepted by verification (excludes bonus).
    pub accepted_speculated: usize,
    /// Tokens emitted this step (accepted + 1 bonus; 1 for baseline).
    pub emitted: usize,
    pub draft_dispatches: u64,
    pub target_dispatches: u64,
    /// Verification positions actually computed this step (non-resident
    /// prefix + tree rows; the `cache::verify_bill` split).
    pub billed_positions: usize,
    /// Prefix positions served from the resident KV cache this step.
    pub cached_positions: usize,
    /// Radix warm-start tokens granted when this step admitted the
    /// sequence (nonzero only on a generation's first step, radix on).
    pub warm_start_tokens: usize,
    /// This step was a prefill chunk round (DESIGN.md §Chunked Prefill):
    /// it computed prompt positions into residency and emitted nothing.
    pub prefill: bool,
    /// Prompt positions computed by this step's prefill chunk (its
    /// billed positions; 0 for decode steps and fully-warm chunks).
    pub prefill_tokens: usize,
    /// Measured wall time per component (Fig 4 buckets).
    pub times: ComponentTimes,
    /// Virtual step latency under the configured hardware regime.
    pub virtual_secs: Option<f64>,
}

/// Statistics for one full generation.
#[derive(Clone, Debug)]
pub struct GenerationStats {
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub steps: Vec<StepStats>,
}

impl GenerationStats {
    pub fn new(prompt_len: usize) -> Self {
        Self {
            prompt_len,
            tokens: Vec::new(),
            steps: Vec::new(),
        }
    }

    /// Record a step's emitted tokens + stats, extending the context and
    /// truncating overshoot so the generation holds exactly
    /// `max_new_tokens` (paper protocol: 128).
    pub fn push_step(
        &mut self,
        mut tokens: Vec<u32>,
        mut step: StepStats,
        ctx: &mut Vec<u32>,
        remaining: usize,
    ) {
        if tokens.len() > remaining {
            tokens.truncate(remaining);
            step.emitted = tokens.len();
        }
        ctx.extend_from_slice(&tokens);
        self.tokens.extend_from_slice(&tokens);
        self.steps.push(step);
    }

    /// Mean tokens emitted per target-model DECODE step — the paper's
    /// "(accepted tokens)" parenthetical, and ≈ the acceleration rate in
    /// the T_t-dominated regime (§5.3). Prefill chunk steps emit nothing
    /// by construction and are excluded from the denominator so the
    /// metric keeps its meaning with chunking on.
    pub fn mean_emitted_per_step(&self) -> f64 {
        let decode = self.steps.iter().filter(|s| !s.prefill).count();
        if decode == 0 {
            return 0.0;
        }
        self.tokens.len() as f64 / decode as f64
    }

    pub fn mean_tree_size(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.tree_size as f64).sum::<f64>() / self.steps.len() as f64
    }

    /// Total measured wall time across all components.
    pub fn total_measured_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.times.total()).sum()
    }

    /// Total virtual regime time (0.0 when no regime configured).
    pub fn total_virtual_secs(&self) -> f64 {
        self.steps.iter().filter_map(|s| s.virtual_secs).sum()
    }

    /// Virtual latency per emitted token — the paper's headline metric.
    pub fn virtual_latency_per_token(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        self.total_virtual_secs() / self.tokens.len() as f64
    }

    /// Measured latency per emitted token.
    pub fn measured_latency_per_token(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        self.total_measured_secs() / self.tokens.len() as f64
    }

    /// Merged component times across steps (Fig 4).
    pub fn aggregate_times(&self) -> ComponentTimes {
        let mut agg = ComponentTimes::new();
        for s in &self.steps {
            agg.merge(&s.times);
        }
        agg
    }

    pub fn total_draft_dispatches(&self) -> u64 {
        self.steps.iter().map(|s| s.draft_dispatches).sum()
    }

    pub fn total_billed_positions(&self) -> u64 {
        self.steps.iter().map(|s| s.billed_positions as u64).sum()
    }

    pub fn total_cached_positions(&self) -> u64 {
        self.steps.iter().map(|s| s.cached_positions as u64).sum()
    }

    /// Radix warm-start tokens granted at admission (cross-request prefix
    /// reuse; nonzero only with `cache.radix=on` and a shared prefix).
    pub fn total_warm_start_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.warm_start_tokens as u64).sum()
    }

    /// Prefill chunk rounds taken before the first speculation round
    /// (0 with chunking off).
    pub fn total_prefill_chunks(&self) -> u64 {
        self.steps.iter().filter(|s| s.prefill).count() as u64
    }

    /// Prompt positions computed by prefill chunk rounds.
    pub fn total_prefill_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.prefill_tokens as u64).sum()
    }

    /// Mean computed verification positions per step — the context-scaling
    /// cost the KV cache flattens (`bench --experiment cache`).
    pub fn billed_positions_per_step(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.total_billed_positions() as f64 / self.steps.len() as f64
    }

    /// Fraction of prefix-or-computed positions served from cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let hit = self.total_cached_positions() as f64;
        let total = hit + self.total_billed_positions() as f64;
        if total <= 0.0 {
            0.0
        } else {
            hit / total
        }
    }
}

/// Aggregates over many generations (one bench cell).
#[derive(Clone, Debug, Default)]
pub struct RunAggregate {
    pub generations: usize,
    pub tokens: usize,
    pub steps: usize,
    pub virtual_secs: f64,
    pub measured_secs: f64,
    pub sum_tree_size: f64,
    pub times: ComponentTimes,
}

impl RunAggregate {
    pub fn add(&mut self, g: &GenerationStats) {
        self.generations += 1;
        self.tokens += g.tokens.len();
        self.steps += g.steps.len();
        self.virtual_secs += g.total_virtual_secs();
        self.measured_secs += g.total_measured_secs();
        self.sum_tree_size += g.steps.iter().map(|s| s.tree_size as f64).sum::<f64>();
        self.times.merge(&g.aggregate_times());
    }

    pub fn emitted_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.tokens as f64 / self.steps as f64
        }
    }

    pub fn virtual_latency_per_token(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            self.virtual_secs / self.tokens as f64
        }
    }

    pub fn measured_latency_per_token(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            self.measured_secs / self.tokens as f64
        }
    }

    pub fn mean_tree_size(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.sum_tree_size / self.steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(emitted: usize, tree: usize, virt: f64) -> StepStats {
        StepStats {
            emitted,
            tree_size: tree,
            virtual_secs: Some(virt),
            ..StepStats::default()
        }
    }

    #[test]
    fn per_step_means() {
        let mut g = GenerationStats::new(4);
        let mut ctx = vec![1, 2, 3, 4];
        for _ in 0..3 {
            g.push_step(vec![7, 8], step(2, 10, 0.5), &mut ctx, 100);
        }
        assert_eq!(g.tokens.len(), 6);
        assert!((g.mean_emitted_per_step() - 2.0).abs() < 1e-12);
        assert!((g.mean_tree_size() - 10.0).abs() < 1e-12);
        assert!((g.total_virtual_secs() - 1.5).abs() < 1e-12);
        assert!((g.virtual_latency_per_token() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn prefill_steps_do_not_dilute_emitted_per_step() {
        let mut g = GenerationStats::new(8);
        let mut ctx = vec![1; 8];
        let chunk = StepStats {
            prefill: true,
            prefill_tokens: 4,
            billed_positions: 4,
            ..StepStats::default()
        };
        g.push_step(Vec::new(), chunk.clone(), &mut ctx, 100);
        g.push_step(Vec::new(), chunk, &mut ctx, 100);
        g.push_step(vec![7, 8], step(2, 10, 0.5), &mut ctx, 100);
        assert_eq!(g.total_prefill_chunks(), 2);
        assert_eq!(g.total_prefill_tokens(), 8);
        assert_eq!(g.tokens.len(), 2);
        // Two chunk rounds + one decode round, but the mean divides by
        // decode rounds only.
        assert_eq!(g.steps.len(), 3);
        assert!((g.mean_emitted_per_step() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn truncates_overshoot() {
        let mut g = GenerationStats::new(1);
        let mut ctx = vec![1];
        g.push_step(vec![5, 6, 7], step(3, 4, 0.1), &mut ctx, 2);
        assert_eq!(g.tokens, vec![5, 6]);
        assert_eq!(ctx, vec![1, 5, 6]);
        assert_eq!(g.steps[0].emitted, 2);
    }

    #[test]
    fn aggregate_combines() {
        let mut g = GenerationStats::new(1);
        let mut ctx = vec![1];
        g.push_step(vec![5], step(1, 8, 0.2), &mut ctx, 10);
        let mut agg = RunAggregate::default();
        agg.add(&g);
        agg.add(&g);
        assert_eq!(agg.generations, 2);
        assert_eq!(agg.tokens, 2);
        assert!((agg.virtual_latency_per_token() - 0.2).abs() < 1e-12);
        assert!((agg.mean_tree_size() - 8.0).abs() < 1e-12);
    }
}
