//! Incremental generation events — the one event vocabulary every layer of
//! the serving stack speaks (DESIGN.md §Serving API v1).
//!
//! A generation no longer produces a single value at the end: each
//! speculation round pushes its accepted chunk as a [`GenEvent::Chunk`]
//! through a per-request channel, and the final [`GenEvent::Done`] carries
//! the aggregate [`Response`]. The FCFS engine path and the continuous
//! batcher feed the SAME event type, so the coordinator and the TCP server
//! route frames without knowing which scheduler produced them.
//!
//! Cancellation travels the other way: a [`CancelToken`] is shared between
//! the submitter (server connection) and the executor (engine round loop /
//! batcher step loop); flipping it makes the executor finish the request
//! early with [`FinishReason::Cancelled`], releasing its scheduler slot and
//! KV residency immediately.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::config::PolicyKind;

/// Destination of one request's event stream.
///
/// Executors (the FCFS worker, the continuous batcher) push every
/// [`GenEvent`] through this trait without knowing where it lands. Two
/// implementations exist:
///
///   - [`mpsc::Sender<GenEvent>`] — the in-process API surface
///     (`RequestHandle`'s channel, drained by `wait()`);
///   - the reactor transport's connection sink (`server/conn.rs`), which
///     serializes the event into a wire frame, pushes it into the
///     connection's bounded outbox and wakes the event loop — no
///     per-request forwarder thread in between.
///
/// `send` returns `false` when the receiver is gone. That is
/// informational only: executors never infer cancellation from a dead
/// sink (cancellation is always explicit via [`CancelToken`]).
pub trait EventSink: Send {
    fn send(&self, ev: GenEvent) -> bool;

    /// Observability hook: the admission path calls this once, before the
    /// request is enqueued, with the request's minted trace id (see
    /// `obs::TraceId`). Sinks that surface a wire protocol echo it in
    /// every frame they emit; the default (and the in-process mpsc sink)
    /// ignores it. Only called when tracing is enabled, so the wire
    /// output is bit-identical with tracing off.
    fn attach_trace(&self, _trace: u64) {}
}

impl EventSink for mpsc::Sender<GenEvent> {
    fn send(&self, ev: GenEvent) -> bool {
        mpsc::Sender::send(self, ev).is_ok()
    }
}

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted `max_new_tokens`.
    #[default]
    Length,
    /// Emitted one of the request's `stop_tokens` (included in the output).
    Stop,
    /// Cancelled by the client (or by its connection dropping).
    Cancelled,
}

impl FinishReason {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Length => "length",
            Self::Stop => "stop",
            Self::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "length" => Self::Length,
            "stop" => Self::Stop,
            "cancelled" => Self::Cancelled,
            _ => return None,
        })
    }
}

/// Per-request generation parameters, carried by the protocol-v1 envelope
/// and honored by both schedulers.
#[derive(Clone, Debug, PartialEq)]
pub struct GenParams {
    pub max_new_tokens: usize,
    pub temperature: f32,
    /// Deterministic sampling stream for this request. `None` falls back to
    /// a server-chosen stream (FCFS: the worker engine's running rng;
    /// continuous: a stream derived from the server-side request id).
    pub seed: Option<u64>,
    /// Generation finishes (reason `stop`) when any of these is emitted;
    /// the stop token itself is included in the output.
    pub stop_tokens: Vec<u32>,
    /// Per-request draft-tree policy override (FCFS swaps the engine
    /// policy; the continuous batcher caps honor it via the fair split).
    pub drafter: Option<PolicyKind>,
    /// Per-request speculation-budget cap: this request's tree never
    /// exceeds `min(engine.tree_budget, token_budget)` speculated tokens
    /// per round.
    pub token_budget: Option<usize>,
}

impl Default for GenParams {
    fn default() -> Self {
        Self {
            max_new_tokens: 128,
            temperature: 0.6,
            seed: None,
            stop_tokens: Vec::new(),
            drafter: None,
            token_budget: None,
        }
    }
}

impl GenParams {
    /// The legacy wire surface: just a length and a temperature.
    pub fn simple(max_new_tokens: usize, temperature: f32) -> Self {
        Self {
            max_new_tokens,
            temperature,
            ..Self::default()
        }
    }
}

/// Shared cancellation flag (submitter side: [`CancelToken::cancel`];
/// executor side: [`CancelToken::is_cancelled`] between rounds).
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Statistics for one speculation round, attached to its chunk.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundStats {
    /// 1-based round index within the request.
    pub round: usize,
    /// Speculated tree size this round (0 for a bare verification row).
    pub tree_size: usize,
    /// Speculated tokens accepted by verification (excludes the bonus).
    pub accepted: usize,
    /// Verification positions computed for this request this round.
    pub billed_positions: usize,
    /// Prefix positions served from the KV cache this round.
    pub cached_positions: usize,
    /// Virtual regime seconds of the round's dispatch (continuous: the
    /// shared dispatch cost; 0 without a regime).
    pub virtual_secs: f64,
}

/// Completed generation (the aggregate the serving layers route; was the
/// one-shot reply before streaming — kept as the `done` frame's payload).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub worker: usize,
    pub tokens: Vec<u32>,
    /// Engine steps taken (target-model dispatches).
    pub steps: usize,
    pub emitted_per_step: f64,
    /// Seconds spent queued before a worker picked the request up.
    pub queue_secs: f64,
    /// Seconds of engine time.
    pub gen_secs: f64,
    /// Seconds from submission to the first emitted chunk (queue wait
    /// included) — the serving-layer TTFT, now pinned to actual emission.
    pub ttft_secs: f64,
    /// Virtual hardware-regime seconds this request experienced (sum of
    /// the step costs of every dispatch it took part in; 0 without a
    /// regime). Under continuous batching a dispatch's cost is shared by
    /// all co-batched sequences, so this is the per-request latency the
    /// serving bench compares across schedulers.
    pub virtual_secs: f64,
    /// Prefix positions this request served from the KV cache across its
    /// dispatches (its share of the worker's hit-rate metric).
    pub cache_hits: u64,
    /// Why the generation stopped.
    pub finish: FinishReason,
}

/// One event on a request's stream: zero or more `Chunk`s, then exactly
/// one `Done` (also on cancellation, with `finish = Cancelled` and the
/// tokens emitted so far).
#[derive(Clone, Debug)]
pub enum GenEvent {
    Chunk { tokens: Vec<u32>, stats: RoundStats },
    Done(Box<Response>),
}

/// Shared chunk-truncation rule for one round's emitted tokens — the ONE
/// definition both the FCFS engine and the continuous batcher apply, so
/// identical requests finish identically on either scheduler: stop-token
/// truncation first (the stop token itself is kept), then the
/// `remaining`-tokens length cap. Returns true when the surviving chunk
/// ends in a stop token — i.e. the generation finishes with
/// [`FinishReason::Stop`] (a stop token cut back off by the length cap
/// does not count).
pub fn truncate_chunk(
    tokens: &mut Vec<u32>,
    stop_tokens: &[u32],
    remaining: usize,
) -> bool {
    if let Some(hit) = tokens.iter().position(|t| stop_tokens.contains(t)) {
        tokens.truncate(hit + 1);
    }
    tokens.truncate(remaining);
    tokens
        .last()
        .map(|t| stop_tokens.contains(t))
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_flips_once_for_all_clones() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(!c.is_cancelled());
        t.cancel();
        assert!(c.is_cancelled());
    }

    #[test]
    fn finish_reason_round_trips() {
        for f in [
            FinishReason::Length,
            FinishReason::Stop,
            FinishReason::Cancelled,
        ] {
            assert_eq!(FinishReason::parse(f.name()), Some(f));
        }
        assert_eq!(FinishReason::parse("eof"), None);
    }

    #[test]
    fn truncate_chunk_orders_stop_before_length_cap() {
        // Stop token kept, tail dropped.
        let mut t = vec![1, 2, 9, 4];
        assert!(truncate_chunk(&mut t, &[9], 10));
        assert_eq!(t, vec![1, 2, 9]);
        // Length cap cuts the stop token back off: not a Stop finish.
        let mut t = vec![1, 2, 9, 4];
        assert!(!truncate_chunk(&mut t, &[9], 2));
        assert_eq!(t, vec![1, 2]);
        // No stop tokens configured.
        let mut t = vec![1, 2, 3];
        assert!(!truncate_chunk(&mut t, &[], 2));
        assert_eq!(t, vec![1, 2]);
        // Stop exactly at the cap boundary survives.
        let mut t = vec![1, 9, 3];
        assert!(truncate_chunk(&mut t, &[9], 2));
        assert_eq!(t, vec![1, 9]);
    }

    #[test]
    fn params_default_matches_legacy_wire_defaults() {
        let p = GenParams::default();
        assert_eq!(p.max_new_tokens, 128);
        assert!((p.temperature - 0.6).abs() < 1e-6);
        assert!(p.stop_tokens.is_empty());
        assert_eq!(GenParams::simple(16, 0.1).max_new_tokens, 16);
    }
}
