//! `LogitModel` backed by the AOT-compiled JAX transformer (PJRT CPU).
//!
//! `next_logits` runs a causal forward padded to the artifact's fixed
//! sequence length; `score_tree` runs the paper's parallel verification: one
//! forward over prefix + speculated tokens with a tree attention mask,
//! returning per-node logits in a single dispatch.

use std::rc::Rc;

use crate::util::error::{Context, Result};

use super::{CallCounts, LogitModel};
use crate::runtime::artifacts::{Artifacts, GraphKey, Role};
use crate::runtime::CompiledModel;
use crate::tree::{NodeId, TokenTree, TreeMask};

pub struct HloModel {
    model: Rc<CompiledModel>,
    role: Role,
    counts: CallCounts,
    /// Reusable causal-mask buffer keyed by live length (the mask is the
    /// only O(S^2) input; rebuilding it per call dominated the profile).
    cached_causal: Option<(usize, Vec<f32>)>,
}

impl HloModel {
    pub fn new(model: Rc<CompiledModel>, role: Role) -> Self {
        Self {
            model,
            role,
            counts: CallCounts::default(),
            cached_causal: None,
        }
    }

    /// Compile-and-wrap helper.
    pub fn load(
        runtime: &mut crate::runtime::PjrtRuntime,
        arts: &Artifacts,
        role: Role,
        seq_len: usize,
        pallas: bool,
    ) -> Result<Self> {
        let key = GraphKey {
            role,
            seq_len,
            pallas,
        };
        let model = runtime.load(arts, key).context("loading model graph")?;
        Ok(Self::new(model, role))
    }

    pub fn seq_len(&self) -> usize {
        self.model.seq_len
    }

    pub fn role(&self) -> Role {
        self.role
    }

    fn causal_mask(&mut self, live: usize) -> &[f32] {
        let s = self.model.seq_len;
        let rebuild = match &self.cached_causal {
            Some((l, _)) => *l != live,
            None => true,
        };
        if rebuild {
            self.cached_causal = Some((live, crate::tree::mask::causal_f32(live, s)));
        }
        &self.cached_causal.as_ref().unwrap().1
    }
}

impl LogitModel for HloModel {
    fn vocab(&self) -> usize {
        self.model.vocab
    }

    fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32> {
        let s = self.model.seq_len;
        let v = self.model.vocab;
        assert!(
            !ctx.is_empty() && ctx.len() <= s,
            "context length {} out of range (seq {s})",
            ctx.len()
        );
        let mut tokens = vec![0i32; s];
        for (i, &t) in ctx.iter().enumerate() {
            tokens[i] = t as i32;
        }
        let positions: Vec<i32> = (0..s as i32).collect();
        let model = self.model.clone();
        let mask = self.causal_mask(ctx.len());
        let logits = model
            .forward(&tokens, &positions, mask)
            .expect("PJRT forward failed");
        self.counts.add_dispatch(1);
        let row = ctx.len() - 1;
        logits[row * v..(row + 1) * v].to_vec()
    }

    fn score_tree(
        &mut self,
        prefix: &[u32],
        tree: &TokenTree,
        order: &[NodeId],
    ) -> Vec<Vec<f32>> {
        let s = self.model.seq_len;
        let v = self.model.vocab;
        let p = prefix.len();
        assert!(p + order.len() <= s, "prefix+tree exceed seq {s}");
        assert!(!prefix.is_empty());

        let mut tokens = vec![0i32; s];
        let mut positions = vec![0i32; s];
        for (i, &t) in prefix.iter().enumerate() {
            tokens[i] = t as i32;
            positions[i] = i as i32;
        }
        for (i, &id) in order.iter().enumerate() {
            tokens[p + i] = tree.node(id).token as i32;
            // node at depth d sits at context position p + d - 1
            positions[p + i] = (p + tree.node(id).depth - 1) as i32;
        }
        for (i, pos) in positions.iter_mut().enumerate().skip(p + order.len()) {
            *pos = (i % s) as i32;
        }
        let mask = TreeMask::from_tree(tree, order).to_full_f32(p, s);
        let logits = self
            .model
            .forward(&tokens, &positions, &mask)
            .expect("PJRT tree forward failed");
        self.counts.add_dispatch((order.len() + 1) as u64);

        let mut rows = Vec::with_capacity(order.len() + 1);
        let root_row = p - 1;
        rows.push(logits[root_row * v..(root_row + 1) * v].to_vec());
        for i in 0..order.len() {
            let r = p + i;
            rows.push(logits[r * v..(r + 1) * v].to_vec());
        }
        rows
    }

    /// Incremental verification, PJRT side: the compiled graphs have no KV
    /// input/output buffers yet, so the real cache reuse is STUBBED — this
    /// re-runs the full tree-masked forward (bit-identical by
    /// construction). Because nothing is actually served from a resident
    /// prefix, no `cached_positions` are credited (the `CallCounts`
    /// contract keeps cached positions disjoint from computed ones).
    /// Wiring paged KV buffers through `python/compile/aot.py` and the
    /// PJRT runtime is an open ROADMAP item.
    fn score_tree_incremental(
        &mut self,
        prefix: &[u32],
        cached_len: usize,
        tree: &TokenTree,
        order: &[NodeId],
    ) -> Vec<Vec<f32>> {
        let _ = cached_len;
        self.score_tree(prefix, tree, order)
    }

    fn call_counts(&self) -> CallCounts {
        self.counts
    }

    fn reset_call_counts(&mut self) {
        self.counts = CallCounts::default();
    }
}
