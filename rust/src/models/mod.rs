//! Model abstraction: everything the algorithms need from a language model
//! is (a) next-token logits for a context and (b) logits at every node of a
//! speculated tree. Three backends implement it:
//!   - `sim`: correlated draft/target distribution simulator (pure rust,
//!     no PJRT) — drives the algorithm-level benches and property tests.
//!   - `hlo`: the AOT-compiled JAX transformer via PJRT CPU.
//!   - `latency`: not a model — a cost ledger (`CallCounter`) that turns
//!     call counts into the paper's hardware-regime virtual latencies.

pub mod hlo;
pub mod sim;

use crate::tree::{NodeId, TokenTree};

/// Wraps a model to attribute inference wall time separately from the
/// logic around it. Both virtual-latency ledgers go through this — the
/// engine's FCFS path (Fig-4 component split) and the continuous
/// batcher's — so "model time billed at regime rates, logic at measured
/// wall time" stays one definition, not two copies.
pub struct TimedModel<'a> {
    inner: &'a mut dyn LogitModel,
    /// Accumulated `next_logits` wall seconds.
    pub secs: f64,
    dispatches_before: u64,
}

impl<'a> TimedModel<'a> {
    pub fn new(inner: &'a mut dyn LogitModel) -> Self {
        let dispatches_before = inner.call_counts().dispatches;
        Self {
            inner,
            secs: 0.0,
            dispatches_before,
        }
    }

    /// Dispatches recorded on the inner model since construction.
    pub fn dispatches(&self) -> u64 {
        self.inner.call_counts().dispatches - self.dispatches_before
    }
}

impl LogitModel for TimedModel<'_> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32> {
        let t = crate::util::Timer::start();
        let out = self.inner.next_logits(ctx);
        self.secs += t.elapsed_secs();
        out
    }

    fn call_counts(&self) -> CallCounts {
        self.inner.call_counts()
    }
}

/// One sequence's slice of a batched (multi-root) verification dispatch:
/// its context, its speculated tree, and the verification order the rows
/// are laid out in. `tree::forest::ForestLayout` maps a `&[ForestItem]` to
/// row offsets and the packed attention mask for backends that execute the
/// whole batch as one masked forward.
pub struct ForestItem<'a> {
    pub prefix: &'a [u32],
    /// Leading prefix positions already resident in the backend's KV cache
    /// (0 = score from scratch). See [`LogitModel::score_tree_incremental`].
    pub cached_len: usize,
    pub tree: &'a TokenTree,
    pub order: &'a [NodeId],
}

/// Per-model call accounting, consumed by the latency regimes: the paper's
/// cost model (§4.3) is `N·T_d + T_t` per step for greedy construction and
/// `D·T_d + T_t` for layered construction, so we track both call units.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CallCounts {
    /// Model invocations that would each be one accelerator dispatch
    /// (a single-position draft step, or one batched layer/tree scoring).
    pub dispatches: u64,
    /// Total positions scored across all dispatches.
    pub positions: u64,
    /// Positions served from a resident KV prefix instead of being
    /// recomputed (incremental scoring; excluded from `positions`).
    pub cached_positions: u64,
}

impl CallCounts {
    pub fn add_dispatch(&mut self, positions: u64) {
        self.dispatches += 1;
        self.positions += positions;
    }

    pub fn add_dispatch_cached(&mut self, positions: u64, cached: u64) {
        self.add_dispatch(positions);
        self.cached_positions += cached;
    }
}

/// A causal LM scoring interface.
///
/// Deliberately NOT `Send`: the HLO backend holds PJRT raw pointers. The
/// coordinator constructs models inside each worker thread instead of
/// sharing them across threads.
pub trait LogitModel {
    fn vocab(&self) -> usize;

    /// Logits over the vocab for the token following `ctx`.
    fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32>;

    /// Logits at the tree root (after `prefix`) and at every node of
    /// `order`, in one verification pass. Row 0 corresponds to the root
    /// (distribution over first-layer speculations); row i+1 to order[i].
    ///
    /// Default implementation walks root-paths with `next_logits` — exact
    /// for any causal backend; the HLO backend overrides it with a single
    /// tree-masked forward (the paper's parallel verification).
    fn score_tree(
        &mut self,
        prefix: &[u32],
        tree: &TokenTree,
        order: &[NodeId],
    ) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(order.len() + 1);
        out.push(self.next_logits(prefix));
        let mut ctx = prefix.to_vec();
        for &id in order {
            ctx.truncate(prefix.len());
            ctx.extend(tree.path_tokens(id));
            out.push(self.next_logits(&ctx));
        }
        out
    }

    /// Session-scoped incremental verification: like
    /// [`LogitModel::score_tree`], but the caller promises the first
    /// `cached_len` prefix positions are resident in the backend's KV cache
    /// (tracked by `cache::CacheManager`), so a cache-aware backend scores
    /// only the non-resident prefix plus the tree rows. MUST return
    /// bit-identical rows to `score_tree` on the same inputs — pinned by
    /// `rust/tests/cache_equivalence.rs`.
    ///
    /// Default implementation ignores the hint and rescores from scratch
    /// (exact for any backend; the ledger then sees no cached positions).
    fn score_tree_incremental(
        &mut self,
        prefix: &[u32],
        cached_len: usize,
        tree: &TokenTree,
        order: &[NodeId],
    ) -> Vec<Vec<f32>> {
        let _ = cached_len;
        self.score_tree(prefix, tree, order)
    }

    /// Score many (prefix, tree) groups in one batched verification
    /// dispatch — the continuous batcher's entry point. Returns, per item,
    /// the same row layout as [`LogitModel::score_tree`] (row 0 = root).
    /// Each item carries its own resident-prefix mark (`cached_len`).
    ///
    /// Default implementation scores items sequentially, which is exact for
    /// any causal backend; batched backends override it with a single
    /// multi-root forward over the `tree::forest` mask layout so the whole
    /// active set costs one accelerator dispatch.
    fn score_forest(&mut self, items: &[ForestItem<'_>]) -> Vec<Vec<Vec<f32>>> {
        items
            .iter()
            .map(|it| {
                self.score_tree_incremental(
                    it.prefix,
                    it.cached_len,
                    it.tree,
                    it.order,
                )
            })
            .collect()
    }

    /// Dispatch/position counters since construction (see `CallCounts`).
    fn call_counts(&self) -> CallCounts {
        CallCounts::default()
    }

    fn reset_call_counts(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::ROOT;

    /// Toy deterministic model: logits favor (last ctx token + 1) mod V.
    struct Succ {
        vocab: usize,
        counts: CallCounts,
    }

    impl LogitModel for Succ {
        fn vocab(&self) -> usize {
            self.vocab
        }

        fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32> {
            self.counts.add_dispatch(1);
            let mut l = vec![0.0; self.vocab];
            let next = (ctx.last().copied().unwrap_or(0) as usize + 1) % self.vocab;
            l[next] = 10.0;
            l
        }

        fn call_counts(&self) -> CallCounts {
            self.counts
        }
    }

    #[test]
    fn default_score_tree_walks_paths() {
        let mut m = Succ {
            vocab: 8,
            counts: CallCounts::default(),
        };
        let mut t = TokenTree::new(2, vec![]);
        let a = t.add_child(ROOT, 3, 0.9);
        let b = t.add_child(a, 4, 0.8);
        let c = t.add_child(ROOT, 5, 0.1);
        let rows = m.score_tree(&[1, 2], &t, &[a, b, c]);
        assert_eq!(rows.len(), 4);
        // root row: successor of 2 is 3
        assert_eq!(crate::util::math::argmax(&rows[0]), 3);
        // row for a (ctx ...2,3): successor 4
        assert_eq!(crate::util::math::argmax(&rows[1]), 4);
        // row for b (ctx ...3,4): successor 5
        assert_eq!(crate::util::math::argmax(&rows[2]), 5);
        // row for c (ctx ...2,5): successor 6
        assert_eq!(crate::util::math::argmax(&rows[3]), 6);
        assert_eq!(m.call_counts().dispatches, 4);
    }

    #[test]
    fn default_score_forest_matches_per_item_score_tree() {
        let mut m = Succ {
            vocab: 8,
            counts: CallCounts::default(),
        };
        let mut t1 = TokenTree::new(2, vec![]);
        let a = t1.add_child(ROOT, 3, 0.9);
        let o1 = vec![a];
        let t2 = TokenTree::new(5, vec![]);
        let o2: Vec<usize> = vec![];
        let items = [
            ForestItem { prefix: &[1, 2], cached_len: 0, tree: &t1, order: &o1 },
            ForestItem { prefix: &[4, 5], cached_len: 1, tree: &t2, order: &o2 },
        ];
        let batched = m.score_forest(&items);
        assert_eq!(batched.len(), 2);
        assert_eq!(batched[0].len(), 2); // root + node a
        assert_eq!(batched[1].len(), 1); // bare root row
        assert_eq!(crate::util::math::argmax(&batched[0][0]), 3);
        assert_eq!(crate::util::math::argmax(&batched[0][1]), 4);
        assert_eq!(crate::util::math::argmax(&batched[1][0]), 6);
    }

    /// The default incremental path must ignore the hint and stay
    /// bit-identical to from-scratch scoring for any backend.
    #[test]
    fn default_incremental_matches_score_tree() {
        let mut m = Succ {
            vocab: 8,
            counts: CallCounts::default(),
        };
        let mut t = TokenTree::new(2, vec![]);
        let a = t.add_child(ROOT, 3, 0.9);
        let b = t.add_child(a, 4, 0.8);
        let order = vec![a, b];
        let want = m.score_tree(&[1, 2], &t, &order);
        for cached in [0usize, 1, 2] {
            let got = m.score_tree_incremental(&[1, 2], cached, &t, &order);
            assert_eq!(got, want, "cached_len {cached}");
        }
    }
}
