//! Model abstraction: everything the algorithms need from a language model
//! is (a) next-token logits for a context and (b) logits at every node of a
//! speculated tree. Three backends implement it:
//!   - `sim`: correlated draft/target distribution simulator (pure rust,
//!     no PJRT) — drives the algorithm-level benches and property tests.
//!   - `hlo`: the AOT-compiled JAX transformer via PJRT CPU.
//!   - `latency`: not a model — a cost ledger (`CallCounter`) that turns
//!     call counts into the paper's hardware-regime virtual latencies.

pub mod hlo;
pub mod sim;

use crate::tree::{NodeId, TokenTree};

/// Per-model call accounting, consumed by the latency regimes: the paper's
/// cost model (§4.3) is `N·T_d + T_t` per step for greedy construction and
/// `D·T_d + T_t` for layered construction, so we track both call units.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CallCounts {
    /// Model invocations that would each be one accelerator dispatch
    /// (a single-position draft step, or one batched layer/tree scoring).
    pub dispatches: u64,
    /// Total positions scored across all dispatches.
    pub positions: u64,
}

impl CallCounts {
    pub fn add_dispatch(&mut self, positions: u64) {
        self.dispatches += 1;
        self.positions += positions;
    }
}

/// A causal LM scoring interface.
///
/// Deliberately NOT `Send`: the HLO backend holds PJRT raw pointers. The
/// coordinator constructs models inside each worker thread instead of
/// sharing them across threads.
pub trait LogitModel {
    fn vocab(&self) -> usize;

    /// Logits over the vocab for the token following `ctx`.
    fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32>;

    /// Logits at the tree root (after `prefix`) and at every node of
    /// `order`, in one verification pass. Row 0 corresponds to the root
    /// (distribution over first-layer speculations); row i+1 to order[i].
    ///
    /// Default implementation walks root-paths with `next_logits` — exact
    /// for any causal backend; the HLO backend overrides it with a single
    /// tree-masked forward (the paper's parallel verification).
    fn score_tree(
        &mut self,
        prefix: &[u32],
        tree: &TokenTree,
        order: &[NodeId],
    ) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(order.len() + 1);
        out.push(self.next_logits(prefix));
        let mut ctx = prefix.to_vec();
        for &id in order {
            ctx.truncate(prefix.len());
            ctx.extend(tree.path_tokens(id));
            out.push(self.next_logits(&ctx));
        }
        out
    }

    /// Dispatch/position counters since construction (see `CallCounts`).
    fn call_counts(&self) -> CallCounts {
        CallCounts::default()
    }

    fn reset_call_counts(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::ROOT;

    /// Toy deterministic model: logits favor (last ctx token + 1) mod V.
    struct Succ {
        vocab: usize,
        counts: CallCounts,
    }

    impl LogitModel for Succ {
        fn vocab(&self) -> usize {
            self.vocab
        }

        fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32> {
            self.counts.add_dispatch(1);
            let mut l = vec![0.0; self.vocab];
            let next = (ctx.last().copied().unwrap_or(0) as usize + 1) % self.vocab;
            l[next] = 10.0;
            l
        }

        fn call_counts(&self) -> CallCounts {
            self.counts
        }
    }

    #[test]
    fn default_score_tree_walks_paths() {
        let mut m = Succ {
            vocab: 8,
            counts: CallCounts::default(),
        };
        let mut t = TokenTree::new(2, vec![]);
        let a = t.add_child(ROOT, 3, 0.9);
        let b = t.add_child(a, 4, 0.8);
        let c = t.add_child(ROOT, 5, 0.1);
        let rows = m.score_tree(&[1, 2], &t, &[a, b, c]);
        assert_eq!(rows.len(), 4);
        // root row: successor of 2 is 3
        assert_eq!(crate::util::math::argmax(&rows[0]), 3);
        // row for a (ctx ...2,3): successor 4
        assert_eq!(crate::util::math::argmax(&rows[1]), 4);
        // row for b (ctx ...3,4): successor 5
        assert_eq!(crate::util::math::argmax(&rows[2]), 5);
        // row for c (ctx ...2,5): successor 6
        assert_eq!(crate::util::math::argmax(&rows[3]), 6);
        assert_eq!(m.call_counts().dispatches, 4);
    }
}
