//! Correlated draft/target simulator.
//!
//! For algorithm-level experiments we need a (draft, target) model pair with
//! a *dialable* KL divergence (paper Eq. 1) and dataset-like entropy — but
//! no accelerator in the loop. `SimSpec` derives, for any context, shared
//! base logits from a context hash (clamped log-normal sharpness), a draft
//! view as base + small jitter, and a target view as base TILTED toward a
//! pivot token drawn from the base distribution — the Hypothesis-1
//! generative story (acceptance calibrated to draft probability, Fig 2).
//! Both roles are deterministic in (spec, context), so draft and target
//! views of the same context are consistent across calls — exactly the
//! property the unbiasedness proofs rely on. DESIGN.md §8 has the full
//! rationale; EXPERIMENTS.md §Calibration the fitted constants.

use super::{CallCounts, LogitModel};
use crate::tree::{NodeId, TokenTree};
use crate::util::rng::splitmix64;
use crate::util::Rng;

/// Shared spec for a draft/target pair.
#[derive(Clone, Copy, Debug)]
pub struct SimSpec {
    pub vocab: usize,
    /// Base logit scale — higher = sharper (lower-entropy) distributions.
    pub concentration: f32,
    /// Target-tilt scale — higher = larger KL(D||T) (never exactly 0:
    /// the draft always keeps its own small jitter).
    pub noise: f32,
    pub seed: u64,
}

impl SimSpec {
    pub fn new(vocab: usize, concentration: f32, noise: f32, seed: u64) -> Self {
        Self {
            vocab,
            concentration,
            noise,
            seed,
        }
    }

    /// Profile-calibrated spec. Concentration models DRAFT/TARGET AGREEMENT
    /// sharpness, calibrated so the per-dataset accepted-tokens ordering
    /// matches the paper's tables (C4 > CNN > OWT for the JF68M pairing —
    /// distillation transfers best on C4-like web text); corpus entropy
    /// ordering lives separately in data::markov.
    pub fn for_dataset(dataset: &str, noise: f32, seed: u64) -> Self {
        // Calibrated (see EXPERIMENTS.md §Calibration) so that the JF68M->7B
        // regime lands on the paper's accepted-tokens range at budget 64.
        let concentration = match dataset {
            "c4" => 4.5,
            "cnn" => 3.9,
            "owt" => 3.1,
            _ => 3.9,
        };
        // Calibration override (used by the tuning sweep in EXPERIMENTS.md).
        let concentration = std::env::var("DYSPEC_SIM_CONC")
            .ok()
            .and_then(|v| v.parse().ok())
            .map(|scale: f32| concentration * scale)
            .unwrap_or(concentration);
        Self::new(512, concentration, noise, seed)
    }

    /// Order-sensitive context hash.
    fn ctx_hash(&self, ctx: &[u32]) -> u64 {
        let mut h = self.seed ^ 0x5851_F42D_4C95_7F2D;
        for &t in ctx {
            let mut s = h ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            h = splitmix64(&mut s);
        }
        h
    }

    /// Shared base logits — the draft model's belief about this context.
    fn base_logits(&self, h: u64) -> (Vec<f32>, f32) {
        // Clamped log-normal sharpness: real LLM next-token distributions at
        // draft temperature 0.6 are never uniform-over-vocab flat (top-prob
        // stays ≳0.2) — unbounded flat tails produce degenerate star trees.
        let mult = {
            let mut rng = Rng::new(h ^ 0x5AA5_5AA5_5AA5_5AA5);
            (1.1 * rng.next_gaussian() as f32).exp().clamp(0.5, 6.0)
        };
        let sharp = self.concentration * mult;
        let mut rng = Rng::new(h);
        // PERF (§Perf bench-driver): paired Box-Muller — one (ln, sqrt,
        // sincos) per TWO logits instead of per one; ~1.8x faster dist
        // generation, identical marginal distribution.
        let mut logits = vec![0f32; self.vocab];
        let mut i = 0;
        while i < self.vocab {
            let u1 = rng.next_f64().max(1e-300);
            let u2 = rng.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            logits[i] = sharp * (r * theta.cos()) as f32;
            if i + 1 < self.vocab {
                logits[i + 1] = sharp * (r * theta.sin()) as f32;
            }
            i += 2;
        }
        (logits, mult)
    }

    /// Target logits: the base belief TILTED toward a pivot token that the
    /// target "actually wants", with the pivot drawn from the base
    /// distribution itself. This is the Hypothesis-1 generative story: the
    /// draft's probability of guessing the target's choice scales with its
    /// own confidence, so acceptance is calibrated to draft probability
    /// (paper Fig 2). The tilt is STRONGER on flat (hard) contexts — where
    /// real drafts diverge most — via the 1/sqrt(sharpness) factor; `noise`
    /// dials the overall KL(D‖T) (paper Eq. 1).
    pub fn target_logits(&self, ctx: &[u32]) -> Vec<f32> {
        let h = self.ctx_hash(ctx);
        let (mut logits, sharp_mult) = self.base_logits(h);
        // Deterministic pivot ~ softmax(base / 0.6).
        let dist = crate::util::math::softmax_temp(&logits, 0.6);
        let mut rng = Rng::new(h ^ 0x7A26_E7A2_6E7A_26E7);
        let u = rng.next_f64() as f32;
        let mut acc = 0.0;
        let mut pivot = 0;
        for (i, &p) in dist.iter().enumerate() {
            acc += p;
            if u < acc {
                pivot = i;
                break;
            }
        }
        let beta = (2.4 * self.noise / sharp_mult.sqrt()).clamp(0.3, 8.0);
        logits[pivot] += beta;
        logits
    }

    /// Draft logits: the base belief plus a small independent perturbation
    /// (the draft neither knows the pivot nor matches the target exactly).
    pub fn draft_logits(&self, ctx: &[u32]) -> Vec<f32> {
        let h = self.ctx_hash(ctx);
        let (mut logits, _) = self.base_logits(h);
        let mut rng = Rng::new(h ^ 0xD5AF_7CAF_0000_0001);
        for l in &mut logits {
            *l += 0.25 * rng.next_gaussian() as f32;
        }
        logits
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Target,
    Draft,
}

/// One role's view of a `SimSpec` pair.
pub struct SimModel {
    spec: SimSpec,
    role: Role,
    counts: CallCounts,
}

impl SimModel {
    pub fn new(spec: SimSpec, role: Role) -> Self {
        Self {
            spec,
            role,
            counts: CallCounts::default(),
        }
    }

    /// Convenience: build the (draft, target) pair.
    pub fn pair(spec: SimSpec) -> (SimModel, SimModel) {
        (
            SimModel::new(spec, Role::Draft),
            SimModel::new(spec, Role::Target),
        )
    }
}

impl LogitModel for SimModel {
    fn vocab(&self) -> usize {
        self.spec.vocab
    }

    fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32> {
        self.counts.add_dispatch(1);
        match self.role {
            Role::Target => self.spec.target_logits(ctx),
            Role::Draft => self.spec.draft_logits(ctx),
        }
    }

    /// Incremental verification: the sim is a pure function of (spec,
    /// context), so KV residency cannot change its logits — rows are
    /// computed exactly as the default `score_tree` walk would, and only
    /// the dispatch accounting reflects the resident prefix. This is the
    /// identity `rust/tests/cache_equivalence.rs` pins.
    fn score_tree_incremental(
        &mut self,
        prefix: &[u32],
        cached_len: usize,
        tree: &TokenTree,
        order: &[NodeId],
    ) -> Vec<Vec<f32>> {
        let cached = cached_len.min(prefix.len()) as u64;
        let total = (prefix.len() + order.len()) as u64;
        self.counts.add_dispatch_cached(total - cached, cached);
        let mut out = Vec::with_capacity(order.len() + 1);
        out.push(match self.role {
            Role::Target => self.spec.target_logits(prefix),
            Role::Draft => self.spec.draft_logits(prefix),
        });
        let mut ctx = prefix.to_vec();
        for &id in order {
            ctx.truncate(prefix.len());
            ctx.extend(tree.path_tokens(id));
            out.push(match self.role {
                Role::Target => self.spec.target_logits(&ctx),
                Role::Draft => self.spec.draft_logits(&ctx),
            });
        }
        out
    }

    fn call_counts(&self) -> CallCounts {
        self.counts
    }

    fn reset_call_counts(&mut self) {
        self.counts = CallCounts::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::{kl_divergence, softmax_temp, tv_distance};

    #[test]
    fn deterministic_per_context() {
        let spec = SimSpec::new(64, 2.0, 0.5, 1);
        let a = spec.target_logits(&[1, 2, 3]);
        let b = spec.target_logits(&[1, 2, 3]);
        assert_eq!(a, b);
        let c = spec.target_logits(&[1, 2, 4]);
        assert_ne!(a, c);
    }

    #[test]
    fn order_sensitive_hash() {
        let spec = SimSpec::new(64, 2.0, 0.5, 1);
        assert_ne!(spec.target_logits(&[1, 2]), spec.target_logits(&[2, 1]));
    }

    #[test]
    fn low_noise_means_low_kl() {
        // noise dials the target tilt; at the minimum tilt the pair is
        // close in KL but never identical (the draft keeps its own jitter).
        let ctxs: Vec<Vec<u32>> = (0..40).map(|i| vec![i, i + 2]).collect();
        let mean_kl = |noise: f32| {
            let spec = SimSpec::new(128, 2.0, noise, 3);
            ctxs.iter()
                .map(|c| {
                    let d = softmax_temp(&spec.draft_logits(c), 1.0);
                    let t = softmax_temp(&spec.target_logits(c), 1.0);
                    kl_divergence(&d, &t)
                })
                .sum::<f32>()
                / ctxs.len() as f32
        };
        assert!(mean_kl(0.1) < mean_kl(2.0));
        assert!(mean_kl(0.1) < 0.5, "low-noise KL too large");
    }

    #[test]
    fn noise_dial_controls_kl() {
        let ctxs: Vec<Vec<u32>> = (0..50).map(|i| vec![i, i + 1, i * 3]).collect();
        let mut kls = Vec::new();
        for noise in [0.25f32, 1.0, 3.0] {
            let spec = SimSpec::new(128, 2.0, noise, 7);
            let mean_kl: f32 = ctxs
                .iter()
                .map(|c| {
                    let d = softmax_temp(&spec.draft_logits(c), 1.0);
                    let t = softmax_temp(&spec.target_logits(c), 1.0);
                    kl_divergence(&d, &t)
                })
                .sum::<f32>()
                / ctxs.len() as f32;
            kls.push(mean_kl);
        }
        assert!(kls[0] < kls[1] && kls[1] < kls[2], "{kls:?}");
    }

    #[test]
    fn concentration_controls_entropy() {
        use crate::util::math::entropy;
        let ctx = vec![9, 8, 7];
        let sharp = SimSpec::new(128, 3.0, 0.0, 1);
        let flat = SimSpec::new(128, 0.5, 0.0, 1);
        let h_sharp = entropy(&softmax_temp(&sharp.target_logits(&ctx), 1.0));
        let h_flat = entropy(&softmax_temp(&flat.target_logits(&ctx), 1.0));
        assert!(h_sharp < h_flat);
    }

    #[test]
    fn pair_views_are_consistent() {
        let spec = SimSpec::new(64, 2.0, 0.5, 11);
        let (mut draft, mut target) = SimModel::pair(spec);
        let ctx = vec![1, 2, 3];
        let d1 = draft.next_logits(&ctx);
        let t1 = target.next_logits(&ctx);
        let d = softmax_temp(&d1, 1.0);
        let t = softmax_temp(&t1, 1.0);
        // correlated but not identical
        assert!(tv_distance(&d, &t) > 0.0);
        assert!(kl_divergence(&d, &t) < 3.0);
        assert_eq!(draft.call_counts().dispatches, 1);
    }

    #[test]
    fn dataset_entropy_ordering() {
        let cnn = SimSpec::for_dataset("cnn", 0.5, 1);
        let owt = SimSpec::for_dataset("owt", 0.5, 1);
        assert!(cnn.concentration > owt.concentration);
    }
}
